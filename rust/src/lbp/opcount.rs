//! Operation-count models: Eqs. 1–2 and Table 1 of the paper.
//!
//! These analytic counts drive the Fig. 4 energy/accuracy sweep, the
//! Fig. 11 cross-design comparison and the Table 1 hardware-cost analysis.
//! Symbols follow the paper: `e` = LBP kernel sampling points, `ch` =
//! channels, `m` = mapping-table elements, `apx` = approximated bits;
//! CNN side: `p×q` = ofmap dims, `r×s` = kernel dims.

/// Per-output-pixel operation counts (reads / comparisons / writes).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCounts {
    pub reads: u64,
    pub comparisons: u64,
    pub writes: u64,
}

impl OpCounts {
    pub fn total(&self) -> u64 {
        self.reads + self.comparisons + self.writes
    }

    pub fn scale(&self, k: u64) -> OpCounts {
        OpCounts {
            reads: self.reads * k,
            comparisons: self.comparisons * k,
            writes: self.writes * k,
        }
    }

    pub fn add(&self, o: &OpCounts) -> OpCounts {
        OpCounts {
            reads: self.reads + o.reads,
            comparisons: self.comparisons + o.comparisons,
            writes: self.writes + o.writes,
        }
    }
}

/// LBP-layer cost parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LbpCost {
    /// e: sampling points per LBP kernel.
    pub e: u64,
    /// ch: number of channels.
    pub ch: u64,
    /// m: mapping-table elements.
    pub m: u64,
    /// apx: approximated bits (0 for LBPNet).
    pub apx: u64,
}

impl LbpCost {
    /// Eq. 1 — per-output-pixel ops for the exact LBPNet:
    /// reads = e·ch + m, comparisons = (e−1)·ch, writes = (e−1)·ch + m.
    pub fn lbpnet_ops(&self) -> OpCounts {
        OpCounts {
            reads: self.e * self.ch + self.m,
            comparisons: (self.e - 1) * self.ch,
            writes: (self.e - 1) * self.ch + self.m,
        }
    }

    /// Eq. 2 — per-output-pixel ops for Ap-LBP with `apx` approximated bits:
    /// reads = (e−apx)·ch + m − apx, comparisons = (e−apx−1)·ch,
    /// writes = (e−apx−1)·ch + m − apx.
    pub fn aplbp_ops(&self) -> OpCounts {
        let ea = self.e.saturating_sub(self.apx);
        OpCounts {
            reads: ea * self.ch + self.m.saturating_sub(self.apx),
            comparisons: ea.saturating_sub(1) * self.ch,
            writes: ea.saturating_sub(1) * self.ch
                + self.m.saturating_sub(self.apx),
        }
    }

    /// Fractional savings of Ap-LBP over LBPNet (total ops).
    pub fn savings(&self) -> f64 {
        let base = self.lbpnet_ops().total() as f64;
        let apx = self.aplbp_ops().total() as f64;
        1.0 - apx / base
    }
}

/// Convolution/LBP layer shape for the Table 1 comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerShape {
    /// ofmap spatial dims p × q.
    pub p: u64,
    pub q: u64,
    /// channels.
    pub ch: u64,
    /// kernel spatial dims r × s (CNN only).
    pub r: u64,
    pub s: u64,
}

/// Table 1 rows: computational + memory cost of a layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CnnCost {
    /// O(N²) multiplications.
    pub muls: u64,
    /// O(N) additions/subtractions/comparisons.
    pub adds: u64,
    /// Memory cost (parameter/access footprint).
    pub memory: u64,
}

impl LayerShape {
    /// Table 1, CNN row: mul = add = p·q·ch·r·s, memory = p·q·r·s.
    pub fn cnn_cost(&self) -> CnnCost {
        let mac = self.p * self.q * self.ch * self.r * self.s;
        CnnCost { muls: mac, adds: mac, memory: self.p * self.q * self.r * self.s }
    }

    /// Table 1, Ap-LBP row: mul = 0, cmp = ch·p·q·(e−apx),
    /// memory = p·q·(e−apx) + (m−apx).
    pub fn aplbp_cost(&self, e: u64, m: u64, apx: u64) -> CnnCost {
        let ea = e.saturating_sub(apx);
        CnnCost {
            muls: 0,
            adds: self.ch * self.p * self.q * ea,
            memory: self.p * self.q * ea + m.saturating_sub(apx),
        }
    }
}

/// Whole-network op totals for an Ap-LBP configuration (all LBP layers),
/// mirroring `python/compile/model.py::ApLbpConfig`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ApLbpOps {
    pub height: u64,
    pub width: u64,
    pub in_channels: u64,
    pub n_lbp_layers: u64,
    pub kernels_per_layer: u64,
    pub e: u64,
    pub m: u64,
    pub apx: u64,
}

impl ApLbpOps {
    /// Paper §6.5 network shapes.
    pub fn for_dataset(dataset: &str, apx: u64) -> Option<Self> {
        match dataset {
            "mnist" | "fashionmnist" => Some(Self {
                height: 28, width: 28, in_channels: 1, n_lbp_layers: 3,
                kernels_per_layer: 8, e: 8, m: 8, apx,
            }),
            "svhn" => Some(Self {
                height: 32, width: 32, in_channels: 3, n_lbp_layers: 8,
                kernels_per_layer: 8, e: 8, m: 8, apx,
            }),
            _ => None,
        }
    }

    /// Channel count entering LBP layer `l` (joint blocks grow it).
    pub fn channels_into(&self, layer: u64) -> u64 {
        self.in_channels + layer * self.kernels_per_layer
    }

    /// Total per-image op counts across all LBP layers, Ap-LBP (Eq. 2).
    pub fn total_aplbp(&self) -> OpCounts {
        self.total_with(|cost| cost.aplbp_ops())
    }

    /// Total per-image op counts across all LBP layers, exact LBPNet (Eq. 1).
    pub fn total_lbpnet(&self) -> OpCounts {
        // LBPNet = apx 0
        let exact = Self { apx: 0, ..*self };
        exact.total_with(|cost| cost.lbpnet_ops())
    }

    fn total_with(&self, f: impl Fn(&LbpCost) -> OpCounts) -> OpCounts {
        let mut total = OpCounts::default();
        let pixels = self.height * self.width;
        for l in 0..self.n_lbp_layers {
            let cost = LbpCost {
                e: self.e,
                ch: self.channels_into(l),
                m: self.m,
                apx: self.apx,
            };
            // per output pixel, per kernel
            let per_pixel = f(&cost);
            total = total.add(&per_pixel.scale(pixels * self.kernels_per_layer));
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq1_eq2_paper_example() {
        // Fig. 3(b) worked example: "the original LBPNet implementation
        // requires 8 comparisons, 14 read and 12 write operations; using
        // Ap-LBP ... 6, 11, and 9 comparisons, read and write".
        // With e = 5 samplings, ch = 2 (channels A and B), m = 4 mapping
        // elements, apx = 1:
        let c = LbpCost { e: 5, ch: 2, m: 4, apx: 1 };
        let lbpnet = c.lbpnet_ops();
        assert_eq!(lbpnet.reads, 14);       // 5·2 + 4
        assert_eq!(lbpnet.comparisons, 8);  // (5−1)·2
        assert_eq!(lbpnet.writes, 12);      // (5−1)·2 + 4
        let ap = c.aplbp_ops();
        assert_eq!(ap.reads, 11);           // (5−1)·2 + 4−1
        assert_eq!(ap.comparisons, 6);      // (5−1−1)·2
        assert_eq!(ap.writes, 9);           // (5−1−1)·2 + 4−1
    }

    #[test]
    fn aplbp_equals_lbpnet_at_apx0() {
        let c = LbpCost { e: 8, ch: 9, m: 8, apx: 0 };
        assert_eq!(c.lbpnet_ops(), c.aplbp_ops());
        assert_eq!(c.savings(), 0.0);
    }

    #[test]
    fn savings_monotone_in_apx() {
        let mut prev = -1.0;
        for apx in 0..5 {
            let c = LbpCost { e: 8, ch: 9, m: 8, apx };
            let s = c.savings();
            assert!(s > prev, "apx={apx}: {s} <= {prev}");
            assert!((0.0..1.0).contains(&s));
            prev = s;
        }
    }

    #[test]
    fn table1_cnn_vs_aplbp() {
        let shape = LayerShape { p: 28, q: 28, ch: 9, r: 3, s: 3 };
        let cnn = shape.cnn_cost();
        assert_eq!(cnn.muls, 28 * 28 * 9 * 9);
        assert_eq!(cnn.adds, cnn.muls);
        assert_eq!(cnn.memory, 28 * 28 * 9);
        let ap = shape.aplbp_cost(8, 8, 2);
        assert_eq!(ap.muls, 0);
        assert_eq!(ap.adds, 9 * 28 * 28 * 6);
        assert_eq!(ap.memory, 28 * 28 * 6 + 6);
        // the paper's point: Ap-LBP removes all O(N²) multiplications
        assert!(ap.adds < cnn.adds + cnn.muls);
    }

    #[test]
    fn network_totals_layers_grow_with_joint() {
        let net = ApLbpOps::for_dataset("mnist", 2).unwrap();
        assert_eq!(net.channels_into(0), 1);
        assert_eq!(net.channels_into(1), 9);
        assert_eq!(net.channels_into(2), 17);
        let ap = net.total_aplbp();
        let lbp = net.total_lbpnet();
        assert!(ap.total() < lbp.total());
        // svhn is the bigger network
        let svhn = ApLbpOps::for_dataset("svhn", 2).unwrap();
        assert!(svhn.total_aplbp().total() > ap.total());
        assert!(ApLbpOps::for_dataset("cifar", 0).is_none());
    }

    #[test]
    fn comparison_reduction_ratio_sane() {
        // paper Fig. 4: apx=2 of 4 mapping bits ⇒ ~42% LBP-layer energy
        // saving; the op-count reduction must land in a comparable band.
        let net = ApLbpOps::for_dataset("mnist", 2).unwrap();
        let ap = net.total_aplbp().total() as f64;
        let lbp = net.total_lbpnet().total() as f64;
        let saving = 1.0 - ap / lbp;
        assert!((0.15..0.6).contains(&saving), "saving {saving}");
    }
}
