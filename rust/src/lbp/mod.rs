//! The paper's LBP computation layer: the parallel in-memory comparison
//! algorithm (Algorithm 1) and the Ap-LBP/LBPNet operation-count models
//! (Eqs. 1–2, Table 1).

pub mod algorithm;
pub mod opcount;

pub use algorithm::{compare_ref, parallel_compare, parallel_compare_into,
                    CompareOutcome};
pub use opcount::{ApLbpOps, CnnCost, LayerShape, LbpCost, OpCounts};
