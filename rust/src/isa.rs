//! The NS-LBP instruction set (paper Table 2), assembler, and executor.
//!
//! NS-LBP is exposed to the programmer as a third-party accelerator with a
//! row-granular ISA: every instruction operates on whole 256-bit rows of
//! one computational sub-array, exploiting the single-cycle multi-row
//! activation of §4.1.
//!
//! | opcode        | semantics (per bit-line i)                      |
//! |---------------|-------------------------------------------------|
//! | `copy`        | r2[i] = r1[i]                                   |
//! | `ini`         | r1[i] = all-'0' or all-'1'                      |
//! | `cmp` (xor2)  | r3[i] = r1[i] ⊕ r2[i]                           |
//! | `search`      | r3[i] = (r1[i] == k[i])                         |
//! | `nand3`       | r4[i] = ¬(r1[i] ∧ r2[i] ∧ r3[i])                |
//! | `nor3`        | r4[i] = ¬(r1[i] ∨ r2[i] ∨ r3[i])                |
//! | `carry`(maj3) | r4[i] = MAJ(r1[i], r2[i], r3[i])                |
//! | `sum` (xor3)  | r4[i] = r1[i] ⊕ r2[i] ⊕ r3[i]                   |
//!
//! The [`Executor`] runs programs against a [`crate::sram::SubArray`],
//! accumulating [`ExecStats`] (cycles, row activations, op mix) that the
//! energy model converts to pJ/ns.  Word-parallel `u64` ops implement the
//! 256 simultaneous bit-lines; their equivalence to the analog
//! sense-amplifier decision path is asserted in tests against
//! [`crate::circuit::sense`].

use std::fmt;

use crate::error::{Error, Result};
use crate::sram::SubArray;

/// Row address inside a sub-array.
pub type Row = usize;

/// Table 2 opcodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Opcode {
    Copy,
    Ini,
    Cmp,    // xor2
    Search, // xnor against key row
    Nand3,
    Nor3,
    Carry, // maj3
    Sum,   // xor3
}

impl Opcode {
    pub const ALL: [Opcode; 8] = [
        Opcode::Copy, Opcode::Ini, Opcode::Cmp, Opcode::Search,
        Opcode::Nand3, Opcode::Nor3, Opcode::Carry, Opcode::Sum,
    ];

    pub fn mnemonic(self) -> &'static str {
        match self {
            Opcode::Copy => "copy",
            Opcode::Ini => "ini",
            Opcode::Cmp => "cmp",
            Opcode::Search => "search",
            Opcode::Nand3 => "nand3",
            Opcode::Nor3 => "nor3",
            Opcode::Carry => "carry",
            Opcode::Sum => "sum",
        }
    }

    pub fn from_mnemonic(s: &str) -> Option<Self> {
        Some(match s {
            "copy" => Opcode::Copy,
            "ini" => Opcode::Ini,
            "cmp" | "xor2" => Opcode::Cmp,
            "search" => Opcode::Search,
            "nand3" => Opcode::Nand3,
            "nor3" => Opcode::Nor3,
            "carry" | "maj3" => Opcode::Carry,
            "sum" | "xor3" => Opcode::Sum,
            _ => return None,
        })
    }

    /// Dense index into per-opcode tables ([`Opcode::ALL`] order).
    pub const fn index(self) -> usize {
        match self {
            Opcode::Copy => 0,
            Opcode::Ini => 1,
            Opcode::Cmp => 2,
            Opcode::Search => 3,
            Opcode::Nand3 => 4,
            Opcode::Nor3 => 5,
            Opcode::Carry => 6,
            Opcode::Sum => 7,
        }
    }

    /// Memory cycles per instruction under the NS-LBP timing: compute ops
    /// resolve in a single read cycle (the paper's headline); `copy`
    /// needs read + write; `ini` is one write.  Every compute result is
    /// latched into `dest` in the same cycle via the decoupled write
    /// port.  The table itself lives in [`crate::hw::CycleTable`] so
    /// alternative hardware profiles can re-price recorded traces.
    pub fn cycles(self) -> u64 {
        crate::hw::CycleTable::NS_LBP.of(self)
    }

    /// Number of simultaneously activated read rows.
    pub fn activated_rows(self) -> u64 {
        match self {
            Opcode::Copy => 1,
            Opcode::Ini => 0,
            Opcode::Cmp | Opcode::Search => 2,
            _ => 3,
        }
    }
}

/// Value written by `ini`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IniValue {
    Zeros,
    Ones,
}

/// One Table-2 instruction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Instruction {
    Copy { src: Row, dest: Row },
    Ini { dest: Row, value: IniValue },
    Cmp { src1: Row, src2: Row, dest: Row },
    Search { src: Row, key: Row, dest: Row },
    Nand3 { src1: Row, src2: Row, src3: Row, dest: Row },
    Nor3 { src1: Row, src2: Row, src3: Row, dest: Row },
    Carry { src1: Row, src2: Row, src3: Row, dest: Row },
    Sum { src1: Row, src2: Row, src3: Row, dest: Row },
}

impl Instruction {
    pub fn opcode(self) -> Opcode {
        match self {
            Instruction::Copy { .. } => Opcode::Copy,
            Instruction::Ini { .. } => Opcode::Ini,
            Instruction::Cmp { .. } => Opcode::Cmp,
            Instruction::Search { .. } => Opcode::Search,
            Instruction::Nand3 { .. } => Opcode::Nand3,
            Instruction::Nor3 { .. } => Opcode::Nor3,
            Instruction::Carry { .. } => Opcode::Carry,
            Instruction::Sum { .. } => Opcode::Sum,
        }
    }
}

impl fmt::Display for Instruction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            Instruction::Copy { src, dest } => write!(f, "copy r{src} -> r{dest}"),
            Instruction::Ini { dest, value } => write!(
                f,
                "ini r{dest}, {}",
                if value == IniValue::Ones { "ones" } else { "zeros" }
            ),
            Instruction::Cmp { src1, src2, dest } => {
                write!(f, "cmp r{src1} r{src2} -> r{dest}")
            }
            Instruction::Search { src, key, dest } => {
                write!(f, "search r{src} k{key} -> r{dest}")
            }
            Instruction::Nand3 { src1, src2, src3, dest } => {
                write!(f, "nand3 r{src1} r{src2} r{src3} -> r{dest}")
            }
            Instruction::Nor3 { src1, src2, src3, dest } => {
                write!(f, "nor3 r{src1} r{src2} r{src3} -> r{dest}")
            }
            Instruction::Carry { src1, src2, src3, dest } => {
                write!(f, "carry r{src1} r{src2} r{src3} -> r{dest}")
            }
            Instruction::Sum { src1, src2, src3, dest } => {
                write!(f, "sum r{src1} r{src2} r{src3} -> r{dest}")
            }
        }
    }
}

/// Assembler: parse the textual form produced by `Display`.
///
/// Grammar per line (comments start with `;`):
/// `copy rA -> rB` | `ini rA, ones|zeros` | `cmp rA rB -> rC`
/// | `search rA kB -> rC` | `nand3|nor3|carry|sum rA rB rC -> rD`
pub fn assemble(text: &str) -> Result<Vec<Instruction>> {
    let mut prog = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split(';').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        prog.push(parse_line(line).map_err(|e| {
            Error::Isa(format!("line {}: {e}", lineno + 1))
        })?);
    }
    Ok(prog)
}

fn parse_reg(tok: &str, prefix: char) -> std::result::Result<Row, String> {
    tok.strip_prefix(prefix)
        .ok_or_else(|| format!("expected {prefix}N, got {tok:?}"))?
        .parse()
        .map_err(|_| format!("bad register number in {tok:?}"))
}

fn parse_line(line: &str) -> std::result::Result<Instruction, String> {
    let norm = line.replace(',', " ");
    let toks: Vec<&str> = norm.split_whitespace().collect();
    let op = Opcode::from_mnemonic(toks[0])
        .ok_or_else(|| format!("unknown opcode {:?}", toks[0]))?;
    let expect_arrow = |i: usize| -> std::result::Result<(), String> {
        if toks.get(i) != Some(&"->") {
            return Err(format!("expected '->' at token {i}"));
        }
        Ok(())
    };
    match op {
        Opcode::Copy => {
            if toks.len() != 4 {
                return Err("copy rA -> rB".into());
            }
            expect_arrow(2)?;
            Ok(Instruction::Copy { src: parse_reg(toks[1], 'r')?,
                                   dest: parse_reg(toks[3], 'r')? })
        }
        Opcode::Ini => {
            if toks.len() != 3 {
                return Err("ini rA, ones|zeros".into());
            }
            let value = match toks[2] {
                "ones" => IniValue::Ones,
                "zeros" => IniValue::Zeros,
                other => return Err(format!("bad ini value {other:?}")),
            };
            Ok(Instruction::Ini { dest: parse_reg(toks[1], 'r')?, value })
        }
        Opcode::Cmp => {
            if toks.len() != 5 {
                return Err("cmp rA rB -> rC".into());
            }
            expect_arrow(3)?;
            Ok(Instruction::Cmp { src1: parse_reg(toks[1], 'r')?,
                                  src2: parse_reg(toks[2], 'r')?,
                                  dest: parse_reg(toks[4], 'r')? })
        }
        Opcode::Search => {
            if toks.len() != 5 {
                return Err("search rA kB -> rC".into());
            }
            expect_arrow(3)?;
            Ok(Instruction::Search { src: parse_reg(toks[1], 'r')?,
                                     key: parse_reg(toks[2], 'k')?,
                                     dest: parse_reg(toks[4], 'r')? })
        }
        Opcode::Nand3 | Opcode::Nor3 | Opcode::Carry | Opcode::Sum => {
            if toks.len() != 6 {
                return Err(format!("{} rA rB rC -> rD", op.mnemonic()));
            }
            expect_arrow(4)?;
            let (src1, src2, src3, dest) = (
                parse_reg(toks[1], 'r')?,
                parse_reg(toks[2], 'r')?,
                parse_reg(toks[3], 'r')?,
                parse_reg(toks[5], 'r')?,
            );
            Ok(match op {
                Opcode::Nand3 => Instruction::Nand3 { src1, src2, src3, dest },
                Opcode::Nor3 => Instruction::Nor3 { src1, src2, src3, dest },
                Opcode::Carry => Instruction::Carry { src1, src2, src3, dest },
                Opcode::Sum => Instruction::Sum { src1, src2, src3, dest },
                _ => unreachable!(),
            })
        }
    }
}

/// Dense per-opcode instruction counters.
///
/// Replaces the historical `BTreeMap<Opcode, u64>`: the counter is
/// bumped on *every executed instruction*, and a map allocated tree
/// nodes inside the innermost compute loops (and again on every
/// `ExecStats` merge).  A fixed 8-slot array is allocation-free and
/// O(1) — part of the allocation-free hot path (EXPERIMENTS.md §Perf).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpcodeCounts([u64; Opcode::ALL.len()]);

impl OpcodeCounts {
    /// Add `n` to `op`'s counter.
    #[inline]
    pub fn add(&mut self, op: Opcode, n: u64) {
        self.0[op.index()] += n;
    }

    /// Set `op`'s counter (map-style API kept for fixtures/tests).
    pub fn insert(&mut self, op: Opcode, n: u64) {
        self.0[op.index()] = n;
    }

    /// `op`'s counter.
    #[inline]
    pub fn get(&self, op: Opcode) -> u64 {
        self.0[op.index()]
    }

    /// Iterate the non-zero counters in [`Opcode::ALL`] order.
    pub fn iter(&self) -> impl Iterator<Item = (Opcode, u64)> + '_ {
        Opcode::ALL
            .iter()
            .filter_map(move |&op| {
                let n = self.0[op.index()];
                if n != 0 { Some((op, n)) } else { None }
            })
    }

    /// Sum the other counters into these.
    pub fn merge(&mut self, other: &OpcodeCounts) {
        for (a, b) in self.0.iter_mut().zip(&other.0) {
            *a += b;
        }
    }
}

impl std::ops::Index<Opcode> for OpcodeCounts {
    type Output = u64;

    fn index(&self, op: Opcode) -> &u64 {
        &self.0[op.index()]
    }
}

/// Execution statistics — the raw material of the energy/latency model.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ExecStats {
    pub instructions: u64,
    pub cycles: u64,
    /// Single-row read accesses (standard decoupled-read).
    pub row_reads: u64,
    /// Row write-backs.
    pub row_writes: u64,
    /// Multi-row compute activations (2- or 3-row).
    pub compute_ops: u64,
    /// Per-opcode instruction counts.
    pub by_opcode: OpcodeCounts,
}

impl ExecStats {
    pub fn merge(&mut self, other: &ExecStats) {
        self.instructions += other.instructions;
        self.cycles += other.cycles;
        self.row_reads += other.row_reads;
        self.row_writes += other.row_writes;
        self.compute_ops += other.compute_ops;
        self.by_opcode.merge(&other.by_opcode);
    }

    fn record(&mut self, op: Opcode) {
        self.instructions += 1;
        self.cycles += op.cycles();
        self.by_opcode.add(op, 1);
        match op {
            Opcode::Copy => {
                self.row_reads += 1;
                self.row_writes += 1;
            }
            Opcode::Ini => self.row_writes += 1,
            _ => {
                self.compute_ops += 1;
                self.row_writes += 1; // result latched into dest
            }
        }
    }

    /// Count one Ctrl-side single-row read (the `NS-LBP_Mem` access of
    /// Algorithm 1).
    pub fn record_ctrl_read(&mut self) {
        self.row_reads += 1;
        self.cycles += 1;
    }
}

/// Executes instructions against one sub-array.
pub struct Executor<'a> {
    pub array: &'a mut SubArray,
    pub stats: ExecStats,
}

impl<'a> Executor<'a> {
    pub fn new(array: &'a mut SubArray) -> Self {
        Self { array, stats: ExecStats::default() }
    }

    /// Execute a single instruction.
    ///
    /// Hot path: all ops run allocation-free through the in-place row
    /// helpers (§Perf — see EXPERIMENTS.md).
    pub fn exec(&mut self, inst: Instruction) -> Result<()> {
        match inst {
            Instruction::Copy { src, dest } => {
                self.array.copy_row(src, dest)?;
            }
            Instruction::Ini { dest, value } => {
                self.array.fill_row(dest, value == IniValue::Ones)?;
            }
            Instruction::Cmp { src1, src2, dest } => {
                self.array.op2_into(src1, src2, dest, |a, b| a ^ b)?;
            }
            Instruction::Search { src, key, dest } => {
                self.array.op2_into(src, key, dest, |a, b| !(a ^ b))?;
            }
            Instruction::Nand3 { src1, src2, src3, dest } => {
                self.array
                    .op3_into(src1, src2, src3, dest, |a, b, c| !(a & b & c))?;
            }
            Instruction::Nor3 { src1, src2, src3, dest } => {
                self.array
                    .op3_into(src1, src2, src3, dest, |a, b, c| !(a | b | c))?;
            }
            Instruction::Carry { src1, src2, src3, dest } => {
                self.array.op3_into(src1, src2, src3, dest, |a, b, c| {
                    (a & b) | (a & c) | (b & c)
                })?;
            }
            Instruction::Sum { src1, src2, src3, dest } => {
                self.array
                    .op3_into(src1, src2, src3, dest, |a, b, c| a ^ b ^ c)?;
            }
        }
        self.stats.record(inst.opcode());
        Ok(())
    }

    /// Execute a whole program.
    pub fn run(&mut self, program: &[Instruction]) -> Result<()> {
        for &inst in program {
            self.exec(inst)?;
        }
        Ok(())
    }

    /// Write one whole row from packed words, accounting it as a single
    /// row-granular write cycle — the bulk load primitive behind the
    /// transposed bit-plane loaders (`MlpSubarrayMap::load_vector`, the
    /// prepacked `load_weight_planes`).  Stat-identical to the loaders'
    /// historical per-row bookkeeping.
    pub fn write_row(&mut self, row: Row, words: &[u64]) -> Result<()> {
        self.array.write_row(row, words)?;
        self.stats.row_writes += 1;
        self.stats.cycles += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::{ideal_outputs, majority3};

    fn setup(rows: &[(usize, u64)]) -> SubArray {
        let mut sa = SubArray::new(16, 128);
        for &(r, pattern) in rows {
            sa.write_row(r, &[pattern, !pattern]).unwrap();
        }
        sa
    }

    #[test]
    fn copy_and_ini() {
        let mut sa = setup(&[(0, 0xDEAD_BEEF_0123_4567)]);
        let mut ex = Executor::new(&mut sa);
        ex.exec(Instruction::Copy { src: 0, dest: 5 }).unwrap();
        ex.exec(Instruction::Ini { dest: 6, value: IniValue::Ones }).unwrap();
        ex.exec(Instruction::Ini { dest: 7, value: IniValue::Zeros }).unwrap();
        assert_eq!(ex.array.read_row(5).unwrap(), ex.array.read_row(0).unwrap());
        assert!(ex.array.read_row(6).unwrap().iter().all(|&w| w == u64::MAX));
        assert!(ex.array.read_row(7).unwrap().iter().all(|&w| w == 0));
        assert_eq!(ex.stats.instructions, 3);
        assert_eq!(ex.stats.cycles, 2 + 1 + 1);
    }

    #[test]
    fn all_boolean_ops_match_gate_semantics() {
        let a = 0b1100u64;
        let b = 0b1010u64;
        let c = 0b1111_0000u64;
        let mut sa = SubArray::new(16, 64);
        sa.write_row(0, &[a]).unwrap();
        sa.write_row(1, &[b]).unwrap();
        sa.write_row(2, &[c]).unwrap();
        let mut ex = Executor::new(&mut sa);
        let cases: [(Instruction, u64, Row); 6] = [
            (Instruction::Cmp { src1: 0, src2: 1, dest: 8 }, a ^ b, 8),
            (Instruction::Search { src: 0, key: 1, dest: 9 }, !(a ^ b), 9),
            (Instruction::Nand3 { src1: 0, src2: 1, src3: 2, dest: 10 },
             !(a & b & c), 10),
            (Instruction::Nor3 { src1: 0, src2: 1, src3: 2, dest: 11 },
             !(a | b | c), 11),
            (Instruction::Carry { src1: 0, src2: 1, src3: 2, dest: 12 },
             (a & b) | (a & c) | (b & c), 12),
            (Instruction::Sum { src1: 0, src2: 1, src3: 2, dest: 13 },
             a ^ b ^ c, 13),
        ];
        for (inst, want, dest) in cases {
            ex.exec(inst).unwrap();
            assert_eq!(ex.array.read_row(dest).unwrap()[0], want, "{inst}");
        }
    }

    #[test]
    fn executor_matches_analog_sense_path() {
        // For every 3-bit memory combination, the word-parallel executor
        // result must equal the circuit model's SA decision.
        for bits in 0u8..8 {
            let (a, b, c) = (bits & 1 == 1, bits & 2 == 2, bits & 4 == 4);
            let ones = a as usize + b as usize + c as usize;
            let sa_out = ideal_outputs(ones);
            let mut sa = SubArray::new(8, 64);
            sa.write_row(0, &[a as u64]).unwrap();
            sa.write_row(1, &[b as u64]).unwrap();
            sa.write_row(2, &[c as u64]).unwrap();
            let mut ex = Executor::new(&mut sa);
            ex.exec(Instruction::Sum { src1: 0, src2: 1, src3: 2, dest: 4 })
                .unwrap();
            ex.exec(Instruction::Carry { src1: 0, src2: 1, src3: 2, dest: 5 })
                .unwrap();
            ex.exec(Instruction::Nand3 { src1: 0, src2: 1, src3: 2, dest: 6 })
                .unwrap();
            ex.exec(Instruction::Nor3 { src1: 0, src2: 1, src3: 2, dest: 7 })
                .unwrap();
            assert_eq!(ex.array.get(4, 0).unwrap(), sa_out.xor3());
            assert_eq!(ex.array.get(5, 0).unwrap(), sa_out.carry());
            assert_eq!(ex.array.get(6, 0).unwrap(), sa_out.nand3());
            assert_eq!(ex.array.get(7, 0).unwrap(), sa_out.nor3());
            assert_eq!(sa_out.carry(), majority3(a, b, c));
        }
    }

    #[test]
    fn full_adder_in_two_cycles() {
        // sum + carry of three rows — the paper's "full adder in one single
        // memory cycle" per output.
        let mut sa = SubArray::new(8, 64);
        sa.write_row(0, &[0b0110]).unwrap();
        sa.write_row(1, &[0b0101]).unwrap();
        sa.write_row(2, &[0b0011]).unwrap();
        let mut ex = Executor::new(&mut sa);
        ex.run(&assemble("sum r0 r1 r2 -> r4\ncarry r0 r1 r2 -> r5").unwrap())
            .unwrap();
        assert_eq!(ex.array.read_row(4).unwrap()[0], 0b0110 ^ 0b0101 ^ 0b0011);
        assert_eq!(ex.stats.cycles, 2);
    }

    #[test]
    fn assembler_roundtrip() {
        let prog = vec![
            Instruction::Copy { src: 1, dest: 2 },
            Instruction::Ini { dest: 3, value: IniValue::Ones },
            Instruction::Cmp { src1: 0, src2: 1, dest: 4 },
            Instruction::Search { src: 0, key: 9, dest: 5 },
            Instruction::Nand3 { src1: 0, src2: 1, src3: 2, dest: 6 },
            Instruction::Nor3 { src1: 0, src2: 1, src3: 2, dest: 7 },
            Instruction::Carry { src1: 0, src2: 1, src3: 2, dest: 8 },
            Instruction::Sum { src1: 0, src2: 1, src3: 2, dest: 9 },
        ];
        let text: String = prog.iter().map(|i| format!("{i}\n")).collect();
        let back = assemble(&text).unwrap();
        assert_eq!(back, prog);
    }

    #[test]
    fn assembler_errors_carry_line_numbers() {
        let err = assemble("copy r0 -> r1\nbogus r1 r2").unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
        assert!(assemble("cmp r0 r1 r2").is_err());
        assert!(assemble("ini r0, maybe").is_err());
        assert!(assemble("copy r0 r1").is_err()); // missing arrow
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let prog = assemble("; header\n\ncopy r0 -> r1 ; trailing\n").unwrap();
        assert_eq!(prog.len(), 1);
    }

    #[test]
    fn out_of_range_row_faults() {
        let mut sa = SubArray::new(4, 64);
        let mut ex = Executor::new(&mut sa);
        assert!(ex.exec(Instruction::Copy { src: 0, dest: 4 }).is_err());
        assert!(ex
            .exec(Instruction::Sum { src1: 0, src2: 1, src3: 9, dest: 2 })
            .is_err());
    }

    #[test]
    fn stats_accounting() {
        let mut sa = SubArray::new(8, 64);
        let mut ex = Executor::new(&mut sa);
        ex.run(&assemble(
            "ini r0, ones\nini r1, zeros\ncmp r0 r1 -> r2\ncopy r2 -> r3",
        ).unwrap())
            .unwrap();
        assert_eq!(ex.stats.instructions, 4);
        assert_eq!(ex.stats.row_writes, 2 + 1 + 1); // 2 ini + cmp latch + copy
        assert_eq!(ex.stats.row_reads, 1); // copy
        assert_eq!(ex.stats.compute_ops, 1);
        assert_eq!(ex.stats.by_opcode[Opcode::Ini], 2);
        assert_eq!(ex.stats.by_opcode.get(Opcode::Cmp), 1);
        assert_eq!(ex.stats.by_opcode.iter().count(), 3); // ini, cmp, copy
        let mut merged = ExecStats::default();
        merged.merge(&ex.stats);
        merged.merge(&ex.stats);
        assert_eq!(merged.instructions, 8);
    }
}
