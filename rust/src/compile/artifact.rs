//! The versioned on-disk `CompiledModel` artifact.
//!
//! Layout (all little-endian):
//!
//! ```text
//! magic  b"NSLBPCM1"
//! u32    artifact format version (1)
//! u64    content hash (FNV-1a of everything after this field)
//! ---- hashed payload ----
//! str    model name            (u32 length + UTF-8 bytes)
//! str    hw profile name
//! u32    cache cols the planes were packed for
//! blob   canonical params      (u64 length + params::synth::serialize)
//! u32    LBP plan count; per plan a u32 length + LbpLayerPlan::to_bytes
//! u8     1 if weight planes follow, else 0
//! blob   mlp1 planes           (u64 length + WeightPlanes::to_bytes)
//! blob   mlp2 planes
//! cost   4 f64 + 2 u64 (see CostEstimate)
//! ```
//!
//! The content hash doubles as the artifact *version*: it changes iff
//! any compiled byte changes, names the file on disk
//! (`<name>-<hash16>.nslbpc`), and is what the serve layer keys shard
//! engine caches by.  `load` re-hashes and rejects any corruption.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::mlp::WeightPlanes;
use crate::model::LbpLayerPlan;
use crate::params::{self, NetParams};

pub const MAGIC: &[u8; 8] = b"NSLBPCM1";
pub const FORMAT_VERSION: u32 = 1;

/// The price stage's per-frame estimate, carried by the artifact so
/// routing can reason about cost without running a frame.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostEstimate {
    /// Total modeled energy per frame [pJ] under `hw_profile`.
    pub energy_pj: f64,
    /// Modeled accelerator time per frame [ns].
    pub time_ns: f64,
    /// Energy per frame with sensing + transmission excluded [pJ].
    pub compute_pj: f64,
    /// DPU share of the energy [pJ].
    pub dpu_pj: f64,
    /// ISA instructions retired per frame.
    pub instructions: u64,
    /// Modeled cycles per frame.
    pub cycles: u64,
}

impl CostEstimate {
    pub(crate) fn to_bytes(self) -> Vec<u8> {
        let mut out = Vec::with_capacity(48);
        for v in [self.energy_pj, self.time_ns, self.compute_pj, self.dpu_pj] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for v in [self.instructions, self.cycles] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    pub(crate) fn from_bytes(bytes: &[u8]) -> Result<Self> {
        if bytes.len() != 48 {
            return Err(Error::Config("cost estimate: bad length".into()));
        }
        let f = |i: usize| {
            f64::from_le_bytes(bytes[i * 8..i * 8 + 8].try_into().unwrap())
        };
        let u = |i: usize| {
            u64::from_le_bytes(bytes[i * 8..i * 8 + 8].try_into().unwrap())
        };
        Ok(Self {
            energy_pj: f(0),
            time_ns: f(1),
            compute_pj: f(2),
            dpu_pj: f(3),
            instructions: u(4),
            cycles: u(5),
        })
    }
}

/// A compiled, versioned model: everything an engine needs, packed.
#[derive(Clone, Debug)]
pub struct CompiledModel {
    pub name: String,
    /// Content hash of the serialized payload — the artifact version.
    pub version: u64,
    /// Name of the hw profile the price stage priced under.
    pub hw_profile: String,
    /// Cache columns (lanes per chunk) the weight planes were packed for.
    pub cols: usize,
    pub params: NetParams,
    /// Canonical params bytes (what `params` parsed from).
    pub params_blob: Vec<u8>,
    pub plans: Vec<LbpLayerPlan>,
    /// `(mlp1, mlp2)` weight bit-planes; `None` for plan-only artifacts.
    pub planes: Option<(WeightPlanes, WeightPlanes)>,
    pub cost: CostEstimate,
}

/// FNV-1a 64-bit — the content hash the whole compile cache keys on.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn push_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn push_blob(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u64).to_le_bytes());
    out.extend_from_slice(b);
}

struct Cursor<'a> {
    data: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.data.len() - self.off < n {
            return Err(Error::Config("artifact truncated".into()));
        }
        let s = &self.data[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        if n > 1 << 16 {
            return Err(Error::Config("artifact: implausible string".into()));
        }
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| Error::Config("artifact: non-UTF-8 string".into()))
    }

    fn blob(&mut self) -> Result<&'a [u8]> {
        let n = self.u64()? as usize;
        self.take(n)
    }
}

impl CompiledModel {
    /// Serialize the hashed payload (everything after the hash field).
    fn payload(&self) -> Vec<u8> {
        let mut out = Vec::new();
        push_str(&mut out, &self.name);
        push_str(&mut out, &self.hw_profile);
        out.extend_from_slice(&(self.cols as u32).to_le_bytes());
        push_blob(&mut out, &self.params_blob);
        out.extend_from_slice(&(self.plans.len() as u32).to_le_bytes());
        for plan in &self.plans {
            push_blob(&mut out, &plan.to_bytes());
        }
        match &self.planes {
            Some((p1, p2)) => {
                out.push(1);
                push_blob(&mut out, &p1.to_bytes());
                push_blob(&mut out, &p2.to_bytes());
            }
            None => out.push(0),
        }
        out.extend_from_slice(&self.cost.to_bytes());
        out
    }

    /// Serialize, stamping `version` from the payload hash.
    pub fn to_bytes(&mut self) -> Vec<u8> {
        let payload = self.payload();
        self.version = fnv1a(&payload);
        let mut out = Vec::with_capacity(20 + payload.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&self.version.to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    /// Deserialize and fully re-validate an artifact: magic, format
    /// version, content hash, params blob, and the shape consistency of
    /// every prepacked table.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut c = Cursor { data: bytes, off: 0 };
        if c.take(8)? != MAGIC {
            return Err(Error::Config("artifact: bad magic".into()));
        }
        let fmt = c.u32()?;
        if fmt != FORMAT_VERSION {
            return Err(Error::Config(format!(
                "artifact: format version {fmt}, this build reads \
                 {FORMAT_VERSION}"
            )));
        }
        let version = c.u64()?;
        let payload = &bytes[c.off..];
        let actual = fnv1a(payload);
        if actual != version {
            return Err(Error::Config(format!(
                "artifact: content hash mismatch (stamped {version:016x}, \
                 payload hashes to {actual:016x}) — corrupted or truncated"
            )));
        }
        let name = c.str()?;
        let hw_profile = c.str()?;
        let cols = c.u32()? as usize;
        let params_blob = c.blob()?.to_vec();
        let params = params::parse(&params_blob)?;
        let n_plans = c.u32()? as usize;
        if n_plans != params.lbp_layers.len() {
            return Err(Error::Config(format!(
                "artifact: {n_plans} plans for {} LBP layers",
                params.lbp_layers.len()
            )));
        }
        let mut plans = Vec::with_capacity(n_plans);
        for _ in 0..n_plans {
            let blob = c.blob()?;
            let (plan, used) = LbpLayerPlan::from_bytes(blob)?;
            if used != blob.len() {
                return Err(Error::Config(
                    "artifact: trailing bytes after plan".into(),
                ));
            }
            plans.push(plan);
        }
        let planes = match c.u8()? {
            0 => None,
            1 => {
                let p1 = WeightPlanes::from_bytes(c.blob()?)?;
                let p2 = WeightPlanes::from_bytes(c.blob()?)?;
                Some((p1, p2))
            }
            v => {
                return Err(Error::Config(format!(
                    "artifact: bad planes marker {v}"
                )))
            }
        };
        let cost = CostEstimate::from_bytes(c.take(48)?)?;
        if c.off != bytes.len() {
            return Err(Error::Config("artifact: trailing bytes".into()));
        }
        let model = Self {
            name, version, hw_profile, cols, params, params_blob, plans,
            planes, cost,
        };
        // cross-validate the tables against the params they claim to
        // serve — a hand-edited artifact that still hashes right (hash
        // recomputed over edited bytes) must not reach an engine
        model.prepacked().plans_for(&model.params)?;
        if model.planes.is_some() {
            model.prepacked().planes_for(&model.params, model.cols)?;
        }
        Ok(model)
    }

    /// Load and validate an artifact file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let bytes = std::fs::read(path).map_err(|e| {
            Error::Config(format!("cannot read {}: {e}", path.display()))
        })?;
        Self::from_bytes(&bytes)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display())))
    }

    /// The compiled tables in the form `EngineBuilder::prepacked` takes.
    pub fn prepacked(&self) -> crate::engine::Prepacked {
        crate::engine::Prepacked {
            plans: self.plans.clone(),
            planes: self.planes.clone(),
        }
    }

    /// Canonical on-disk filename for this artifact version.
    pub fn filename(&self) -> String {
        format!("{}-{:016x}.nslbpc", self.name, self.version)
    }

    /// Write the artifact into `dir` under its canonical name.
    pub fn write_to(&mut self, dir: impl AsRef<Path>) -> Result<PathBuf> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir).map_err(|e| {
            Error::Config(format!("cannot create {}: {e}", dir.display()))
        })?;
        let bytes = self.to_bytes();
        let path = dir.join(self.filename());
        std::fs::write(&path, bytes).map_err(|e| {
            Error::Config(format!("cannot write {}: {e}", path.display()))
        })?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model;
    use crate::params::synth::{serialize, synth_params};

    fn sample() -> CompiledModel {
        let (blob, params) = synth_params(9);
        let plans = model::plan_layers(&params);
        let p1 = WeightPlanes::pack(&params.mlp1, params.config.w_bits, 256)
            .unwrap();
        let p2 = WeightPlanes::pack(&params.mlp2, params.config.w_bits, 256)
            .unwrap();
        CompiledModel {
            name: "sample".into(),
            version: 0,
            hw_profile: "ns_lbp_65nm".into(),
            cols: 256,
            params,
            params_blob: blob,
            plans,
            planes: Some((p1, p2)),
            cost: CostEstimate {
                energy_pj: 1.5,
                time_ns: 2.5,
                compute_pj: 1.0,
                dpu_pj: 0.25,
                instructions: 10,
                cycles: 20,
            },
        }
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let mut m = sample();
        let bytes = m.to_bytes();
        let r = CompiledModel::from_bytes(&bytes).unwrap();
        assert_eq!(r.name, m.name);
        assert_eq!(r.version, m.version);
        assert_eq!(r.hw_profile, m.hw_profile);
        assert_eq!(r.cols, m.cols);
        assert_eq!(r.params, m.params);
        assert_eq!(r.params_blob, serialize(&m.params));
        assert_eq!(r.plans.len(), m.plans.len());
        assert_eq!(r.plans[0].lin_offsets, m.plans[0].lin_offsets);
        let (a, b) = (r.planes.unwrap(), m.planes.clone().unwrap());
        assert_eq!(a.0.plane(0, 0, 0).unwrap(), b.0.plane(0, 0, 0).unwrap());
        assert_eq!(a.1.to_bytes(), b.1.to_bytes());
        assert_eq!(r.cost, m.cost);
    }

    #[test]
    fn version_tracks_content() {
        let mut a = sample();
        let va = {
            a.to_bytes();
            a.version
        };
        let mut b = sample();
        b.cost.energy_pj += 1.0;
        b.to_bytes();
        assert_ne!(va, b.version);
        let mut c = sample();
        c.to_bytes();
        assert_eq!(va, c.version);
    }

    #[test]
    fn corruption_is_rejected() {
        let mut m = sample();
        let bytes = m.to_bytes();
        for i in [0usize, 9, 12, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[i] ^= 0xFF;
            assert!(CompiledModel::from_bytes(&bad).is_err(), "byte {i}");
        }
        assert!(CompiledModel::from_bytes(&bytes[..bytes.len() - 2]).is_err());
    }

    #[test]
    fn chaos_plane_corruption_is_always_detected() {
        // the fault plan corrupts a pushed artifact by xor-ing one byte
        // (0x40) at a schedule-chosen position; the content hash must
        // catch every position the schedule can pick, or a corrupted
        // model could reach an engine during a chaos run
        let mut m = sample();
        let bytes = m.to_bytes();
        let mut cfg = crate::config::FaultsConfig::default();
        cfg.enabled = true;
        cfg.artifact_corrupt_prob = 1.0;
        for attempt in 0..64 {
            let pos = crate::faults::artifact_corruption(
                &cfg, 0, attempt, bytes.len(),
            ).expect("corrupt_prob 1.0 must pick a byte");
            assert!(pos < bytes.len());
            let mut bad = bytes.clone();
            bad[pos] ^= 0x40;
            assert!(
                CompiledModel::from_bytes(&bad).is_err(),
                "chaos flip at byte {pos} went undetected"
            );
        }
    }
}
