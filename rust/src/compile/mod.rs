//! Staged model compilation (`ns-lbp compile`).
//!
//! Lowers a [`ModelSpec`] TOML description into a versioned
//! [`CompiledModel`] artifact through four stages, each cached on disk
//! by a content-hash key so recompiles are incremental:
//!
//! | stage     | input key                          | output             |
//! |-----------|------------------------------------|--------------------|
//! | `analyze` | spec fields + weight-file bytes    | canonical params   |
//! | `map`     | params blob                        | LBP gather plans   |
//! | `pack`    | params blob + cache cols           | MLP weight planes  |
//! | `price`   | params blob + cols + hw profile    | per-frame cost     |
//!
//! A second compile of an unchanged spec hits every cache and does
//! **zero** packing work — the stage outputs are read back and only
//! deserialized.  Changing the seed (or the weight file's bytes)
//! invalidates `analyze` and everything downstream; changing only the
//! hw profile re-prices without re-packing.  The final artifact is
//! written to `<out_dir>/<name>-<version16>.nslbpc` where `version` is
//! the FNV-1a hash of the serialized payload; engines built from it via
//! [`crate::engine::EngineBuilder::prepacked`] are bit-identical to
//! from-params engines (gated by `rust/tests/compile.rs`).

pub mod artifact;
pub mod spec;

pub use artifact::{fnv1a, CompiledModel, CostEstimate};
pub use spec::{ModelSpec, WeightSource};

use std::path::{Path, PathBuf};

use crate::config::SystemConfig;
use crate::engine::{ArchSim, BackendKind, Engine, EngineConfig};
use crate::error::{Error, Result};
use crate::mlp::WeightPlanes;
use crate::model::LbpLayerPlan;
use crate::params::{self, NetParams};

/// Where stage caches and finished artifacts land; defaults come from
/// the `[compile]` config section.
#[derive(Clone, Debug)]
pub struct CompileOptions {
    pub out_dir: PathBuf,
    pub cache_dir: PathBuf,
}

impl CompileOptions {
    pub fn from_system(system: &SystemConfig) -> Self {
        Self {
            out_dir: PathBuf::from(&system.compile.out_dir),
            cache_dir: PathBuf::from(&system.compile.cache_dir),
        }
    }
}

/// One stage's outcome: whether its keyed output was already on disk.
#[derive(Clone, Debug)]
pub struct StageReport {
    pub stage: &'static str,
    pub cached: bool,
    /// The stage's cache key (hex of the input hash).
    pub key: u64,
}

/// What `compile` did, for the CLI and for cache-behavior tests.
#[derive(Clone, Debug)]
pub struct CompileReport {
    pub name: String,
    pub version: u64,
    pub path: PathBuf,
    pub stages: Vec<StageReport>,
    pub cost: CostEstimate,
}

impl CompileReport {
    /// True when every stage came from the cache (an unchanged spec).
    pub fn all_cached(&self) -> bool {
        self.stages.iter().all(|s| s.cached)
    }

    pub fn print(&self) {
        println!("compiled {} -> {}", self.name, self.path.display());
        println!("  version  {:016x}", self.version);
        for s in &self.stages {
            println!(
                "  {:<8} {:016x}  {}",
                s.stage, s.key,
                if s.cached { "cached" } else { "built" }
            );
        }
        let c = &self.cost;
        println!(
            "  cost     {:.3} uJ/frame ({:.3} uJ compute, {:.3} uJ dpu), \
             {:.2} us, {} instrs / {} cycles",
            c.energy_pj / 1e6, c.compute_pj / 1e6, c.dpu_pj / 1e6,
            c.time_ns / 1e3, c.instructions, c.cycles
        );
    }

    pub fn to_json(&self) -> String {
        use crate::obs::json;
        let mut s = String::from("{");
        json::push_str_field(&mut s, "name", &self.name);
        json::push_str_field(&mut s, "version",
                             &format!("{:016x}", self.version));
        json::push_str_field(&mut s, "path",
                             &self.path.display().to_string());
        s.push_str("\"stages\":[");
        for (i, st) in self.stages.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"stage\":\"{}\",\"cached\":{},\"key\":\"{:016x}\"}}",
                st.stage, st.cached, st.key
            ));
        }
        s.push_str("],\"cost\":{");
        let c = &self.cost;
        json::push_f64_field(&mut s, "energy_pj", c.energy_pj);
        json::push_f64_field(&mut s, "time_ns", c.time_ns);
        json::push_f64_field(&mut s, "compute_pj", c.compute_pj);
        json::push_f64_field(&mut s, "dpu_pj", c.dpu_pj);
        json::push_u64_field(&mut s, "instructions", c.instructions);
        json::push_u64_field(&mut s, "cycles", c.cycles);
        s.pop();
        s.push_str("}}");
        s
    }
}

/// Hash stage-name + input material into a cache key: the name keeps
/// two stages with identical input bytes from sharing a file.
fn stage_key(stage: &str, parts: &[&[u8]]) -> u64 {
    let mut material = Vec::new();
    material.extend_from_slice(stage.as_bytes());
    for p in parts {
        material.extend_from_slice(&(p.len() as u64).to_le_bytes());
        material.extend_from_slice(p);
    }
    fnv1a(&material)
}

/// Run one stage through the on-disk cache: a keyed hit is read back
/// verbatim, a miss computes and persists.
fn stage(cache_dir: &Path, name: &'static str, key: u64,
         stages: &mut Vec<StageReport>,
         compute: impl FnOnce() -> Result<Vec<u8>>) -> Result<Vec<u8>> {
    let path = cache_dir.join(format!("{name}-{key:016x}.bin"));
    if let Ok(bytes) = std::fs::read(&path) {
        stages.push(StageReport { stage: name, cached: true, key });
        return Ok(bytes);
    }
    let bytes = compute()?;
    std::fs::create_dir_all(cache_dir).map_err(|e| {
        Error::Config(format!("cannot create {}: {e}", cache_dir.display()))
    })?;
    std::fs::write(&path, &bytes).map_err(|e| {
        Error::Config(format!("cannot write {}: {e}", path.display()))
    })?;
    stages.push(StageReport { stage: name, cached: false, key });
    Ok(bytes)
}

fn encode_plans(plans: &[LbpLayerPlan]) -> Vec<u8> {
    let mut out = (plans.len() as u32).to_le_bytes().to_vec();
    for p in plans {
        out.extend_from_slice(&p.to_bytes());
    }
    out
}

fn decode_plans(bytes: &[u8]) -> Result<Vec<LbpLayerPlan>> {
    if bytes.len() < 4 {
        return Err(Error::Config("plan cache entry truncated".into()));
    }
    let n = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
    let mut off = 4;
    let mut plans = Vec::with_capacity(n);
    for _ in 0..n {
        let (plan, used) = LbpLayerPlan::from_bytes(&bytes[off..])?;
        off += used;
        plans.push(plan);
    }
    if off != bytes.len() {
        return Err(Error::Config("plan cache entry has trailing bytes".into()));
    }
    Ok(plans)
}

fn encode_planes(p1: &WeightPlanes, p2: &WeightPlanes) -> Vec<u8> {
    let mut out = Vec::new();
    for b in [p1.to_bytes(), p2.to_bytes()] {
        out.extend_from_slice(&(b.len() as u64).to_le_bytes());
        out.extend_from_slice(&b);
    }
    out
}

fn take_blob<'a>(bytes: &'a [u8], off: &mut usize) -> Result<&'a [u8]> {
    if bytes.len() - *off < 8 {
        return Err(Error::Config("plane cache entry truncated".into()));
    }
    let n = u64::from_le_bytes(bytes[*off..*off + 8].try_into().unwrap())
        as usize;
    *off += 8;
    if bytes.len() - *off < n {
        return Err(Error::Config("plane cache entry truncated".into()));
    }
    let s = &bytes[*off..*off + n];
    *off += n;
    Ok(s)
}

fn decode_planes(bytes: &[u8]) -> Result<(WeightPlanes, WeightPlanes)> {
    let mut off = 0;
    let p1 = WeightPlanes::from_bytes(take_blob(bytes, &mut off)?)?;
    let p2 = WeightPlanes::from_bytes(take_blob(bytes, &mut off)?)?;
    if off != bytes.len() {
        return Err(Error::Config("plane cache entry has trailing bytes".into()));
    }
    Ok((p1, p2))
}

/// The price stage's compute: run one synthetic frame through an
/// architectural engine (full LBP + MLP simulation) built from the
/// tables the earlier stages produced, and distill its `Telemetry`.
fn price(params: &NetParams, system: &SystemConfig,
         plans: &[LbpLayerPlan], planes: &(WeightPlanes, WeightPlanes))
    -> Result<CostEstimate>
{
    let config = EngineConfig {
        system: system.clone(),
        arch: ArchSim { lbp: true, mlp: true, early_exit: false },
        shard: None,
    };
    let prepacked = std::sync::Arc::new(crate::engine::Prepacked {
        plans: plans.to_vec(),
        planes: Some(planes.clone()),
    });
    let mut engine = Engine::builder()
        .config(config)
        .params(params.clone())
        .backend(BackendKind::Architectural)
        .no_cross_check()
        .prepacked(prepacked)
        .build()?;
    let frames = crate::testing::synth_frames(params, 1, 11)?;
    let t = engine.infer_batch(&frames)?.telemetry();
    let e = &t.cost.energy;
    Ok(CostEstimate {
        energy_pj: t.cost.total_pj(),
        time_ns: t.cost.time_ns,
        compute_pj: e.compute_pj + e.read_pj + e.write_pj + e.ctrl_pj,
        dpu_pj: e.dpu_pj,
        instructions: t.exec.instructions,
        cycles: t.exec.cycles,
    })
}

/// Compile `spec` straight to a [`CompiledModel`] in memory — every
/// stage computed, nothing cached or written.  The version is stamped.
/// This is what tests and `Server::push_model` callers use when no
/// artifact file is wanted.
pub fn build_model(spec: &ModelSpec, system: &SystemConfig)
    -> Result<CompiledModel>
{
    let (params_blob, params) = spec.build_params()?;
    let plans = crate::model::plan_layers(&params);
    let cols = system.cache.cols;
    let w_bits = params.config.w_bits;
    let p1 = WeightPlanes::pack(&params.mlp1, w_bits, cols)?;
    let p2 = WeightPlanes::pack(&params.mlp2, w_bits, cols)?;
    let cost = price(&params, system, &plans, &(p1.clone(), p2.clone()))?;
    let mut model = CompiledModel {
        name: spec.name.clone(),
        version: 0,
        hw_profile: system.hw_profile().name.clone(),
        cols,
        params,
        params_blob,
        plans,
        planes: Some((p1, p2)),
        cost,
    };
    model.to_bytes(); // stamp the content-hash version
    Ok(model)
}

/// The staged, cached pipeline: analyze → map → pack → price, then
/// write the versioned artifact into `opts.out_dir`.
pub fn compile(spec: &ModelSpec, system: &SystemConfig,
               opts: &CompileOptions) -> Result<(CompiledModel, CompileReport)>
{
    let cache = opts.cache_dir.as_path();
    let mut stages = Vec::new();

    // analyze: spec → canonical params bytes
    let fingerprint = spec.fingerprint()?;
    let analyze_key = stage_key("analyze", &[&fingerprint]);
    let params_blob = stage(cache, "analyze", analyze_key, &mut stages, || {
        Ok(spec.build_params()?.0)
    })?;
    let params = params::parse(&params_blob).map_err(|e| {
        Error::Config(format!("corrupt analyze cache entry: {e}"))
    })?;

    // map: params → per-layer gather plans
    let map_key = stage_key("map", &[&params_blob]);
    let plan_bytes = stage(cache, "map", map_key, &mut stages, || {
        Ok(encode_plans(&crate::model::plan_layers(&params)))
    })?;
    let plans = decode_plans(&plan_bytes)?;

    // pack: params + cache geometry → MLP weight bit-planes
    let cols = system.cache.cols;
    let cols_bytes = (cols as u64).to_le_bytes();
    let pack_key = stage_key("pack", &[&params_blob, &cols_bytes]);
    let plane_bytes = stage(cache, "pack", pack_key, &mut stages, || {
        let w_bits = params.config.w_bits;
        let p1 = WeightPlanes::pack(&params.mlp1, w_bits, cols)?;
        let p2 = WeightPlanes::pack(&params.mlp2, w_bits, cols)?;
        Ok(encode_planes(&p1, &p2))
    })?;
    let planes = decode_planes(&plane_bytes)?;

    // price: one frame through the arch sim under the effective profile
    let profile_toml = system.hw_profile().to_toml();
    let price_key = stage_key(
        "price", &[&params_blob, &cols_bytes, profile_toml.as_bytes()]);
    let cost_bytes = stage(cache, "price", price_key, &mut stages, || {
        Ok(price(&params, system, &plans, &planes)?.to_bytes())
    })?;
    let cost = CostEstimate::from_bytes(&cost_bytes)?;

    let mut model = CompiledModel {
        name: spec.name.clone(),
        version: 0,
        hw_profile: system.hw_profile().name.clone(),
        cols,
        params,
        params_blob,
        plans,
        planes: Some(planes),
        cost,
    };
    let path = model.write_to(&opts.out_dir)?;
    let report = CompileReport {
        name: model.name.clone(),
        version: model.version,
        path,
        stages,
        cost,
    };
    Ok((model, report))
}
