//! `ModelSpec`: the TOML model description `ns-lbp compile` lowers.
//!
//! A spec names the network geometry (image dims, LBP layer stack,
//! approximation degree, dataset head) plus where the weights come from:
//! either a deterministic synthesis seed (`seed = 42`) or a params file
//! (`weights = "mnist.params.bin"`).  Every geometry key defaults to the
//! value `params::synth::default_config()` has always used, so a minimal
//! spec is just a `[model]` table.  See `configs/models/*.toml` and
//! EXPERIMENTS.md §Compile for the format.

use std::path::{Path, PathBuf};

use crate::config::ConfigFile;
use crate::error::{Error, Result};
use crate::params::{self, synth, NetConfig, NetParams};

/// Every key a spec file may set; anything else is a typo and errors.
const KNOWN: &[&str] = &[
    "model.name",
    "model.seed",
    "model.weights",
    "geometry.height",
    "geometry.width",
    "geometry.channels",
    "lbp.layers",
    "lbp.kernels",
    "lbp.e",
    "lbp.window",
    "approx.code",
    "approx.pixel",
    "head.pool",
    "head.act_bits",
    "head.w_bits",
    "head.hidden",
    "head.classes",
];

/// Keys that describe the network shape (mutually exclusive with
/// `model.weights`, which carries its own geometry).
const GEOMETRY_KEYS: &[&str] = &[
    "geometry.height",
    "geometry.width",
    "geometry.channels",
    "lbp.layers",
    "lbp.kernels",
    "lbp.e",
    "lbp.window",
    "approx.code",
    "approx.pixel",
    "head.pool",
    "head.act_bits",
    "head.w_bits",
    "head.hidden",
    "head.classes",
];

/// Where a spec's weights come from.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WeightSource {
    /// Deterministic synthesis via `params::synth::synth_params_for`.
    Seed(u64),
    /// A serialized params file (geometry comes from the file).
    File(PathBuf),
}

/// A parsed, validated model spec.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    /// Artifact name; embeds in the output filename, so it is
    /// restricted to ASCII alphanumerics plus `_`/`-`/`.`.
    pub name: String,
    pub source: WeightSource,
    /// The declared geometry (`Seed` sources only; a `File` source's
    /// geometry is read from the params file during analysis).
    pub config: NetConfig,
}

impl ModelSpec {
    /// Parse a spec from TOML text.  Relative `weights` paths resolve
    /// against `dir` (the spec file's directory).
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let f = ConfigFile::parse(text)?;
        for key in f.keys() {
            if !KNOWN.contains(&key) {
                return Err(Error::Config(format!(
                    "model spec: unknown key {key:?}"
                )));
            }
        }
        let name = f.get_str("model.name", "")?;
        if name.is_empty() {
            return Err(Error::Config("model spec: model.name is required".into()));
        }
        if !name.chars().all(|c| {
            c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.')
        }) {
            return Err(Error::Config(format!(
                "model spec: name {name:?} must be ASCII alphanumeric/_-."
            )));
        }
        let source = if f.contains("model.weights") {
            if let Some(k) = GEOMETRY_KEYS.iter().find(|k| f.contains(k)) {
                return Err(Error::Config(format!(
                    "model spec: {k} conflicts with model.weights (the \
                     params file defines the geometry)"
                )));
            }
            if f.contains("model.seed") {
                return Err(Error::Config(
                    "model spec: set model.seed or model.weights, not both"
                        .into(),
                ));
            }
            let p = PathBuf::from(f.get_str("model.weights", "")?);
            WeightSource::File(if p.is_relative() { dir.join(p) } else { p })
        } else {
            let seed = f.get_i64("model.seed", 7)?;
            WeightSource::Seed(seed as u64)
        };
        let d = synth::default_config();
        let config = NetConfig {
            height: f.get_usize("geometry.height", d.height)?,
            width: f.get_usize("geometry.width", d.width)?,
            in_channels: f.get_usize("geometry.channels", d.in_channels)?,
            n_lbp_layers: f.get_usize("lbp.layers", d.n_lbp_layers)?,
            kernels_per_layer: f.get_usize("lbp.kernels", d.kernels_per_layer)?,
            e: f.get_usize("lbp.e", d.e)?,
            window: f.get_usize("lbp.window", d.window)?,
            apx_code: f.get_usize("approx.code", d.apx_code)?,
            apx_pixel: f.get_usize("approx.pixel", d.apx_pixel)?,
            pool: f.get_usize("head.pool", d.pool)?,
            act_bits: f.get_usize("head.act_bits", d.act_bits)?,
            w_bits: f.get_usize("head.w_bits", d.w_bits)?,
            hidden: f.get_usize("head.hidden", d.hidden)?,
            n_classes: f.get_usize("head.classes", d.n_classes)?,
        };
        let spec = Self { name, source, config };
        spec.validate()?;
        Ok(spec)
    }

    /// Load a spec file; relative weight paths resolve against its
    /// directory.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::Config(format!("cannot read {}: {e}", path.display()))
        })?;
        let dir = path.parent().unwrap_or_else(|| Path::new("."));
        Self::parse(&text, dir)
    }

    fn validate(&self) -> Result<()> {
        if let WeightSource::Seed(_) = self.source {
            let c = &self.config;
            params::validate_config(c)?;
            // synthesis-only constraints on top of the params format's:
            // a 1x1 window has no non-pivot point to sample, and an
            // empty layer/head would make the packed artifact degenerate
            if c.window < 3 {
                return Err(Error::Config(
                    "model spec: lbp.window must be >= 3".into(),
                ));
            }
            if c.kernels_per_layer == 0 || c.hidden == 0 || c.n_classes == 0 {
                return Err(Error::Config(
                    "model spec: lbp.kernels, head.hidden and head.classes \
                     must be non-zero".into(),
                ));
            }
        }
        Ok(())
    }

    /// The analyze stage's compute: canonical params bytes plus their
    /// parsed form.  Synthesized weights serialize deterministically;
    /// file weights are parsed (validating them) and re-serialized so
    /// the blob is canonical either way.
    pub fn build_params(&self) -> Result<(Vec<u8>, NetParams)> {
        match &self.source {
            WeightSource::Seed(seed) => {
                Ok(synth::synth_params_for(self.config, *seed))
            }
            WeightSource::File(path) => {
                let p = params::load(path)?;
                Ok((synth::serialize(&p), p))
            }
        }
    }

    /// Stable fingerprint text for the analyze-stage cache key: every
    /// spec field in a fixed order, plus the weight file's bytes when
    /// the source is a file (so editing the file invalidates the stage
    /// even though the path is unchanged).
    pub fn fingerprint(&self) -> Result<Vec<u8>> {
        let c = &self.config;
        let mut out = format!(
            "name={}\ngeometry={}x{}x{}\nlbp={}x{} e={} window={}\n\
             approx={}/{}\nhead=pool{} a{} w{} h{} c{}\n",
            self.name, c.height, c.width, c.in_channels, c.n_lbp_layers,
            c.kernels_per_layer, c.e, c.window, c.apx_code, c.apx_pixel,
            c.pool, c.act_bits, c.w_bits, c.hidden, c.n_classes
        )
        .into_bytes();
        match &self.source {
            WeightSource::Seed(seed) => {
                out.extend_from_slice(format!("seed={seed}\n").as_bytes());
            }
            WeightSource::File(path) => {
                out.extend_from_slice(b"weights=\n");
                out.extend_from_slice(&std::fs::read(path).map_err(|e| {
                    Error::Config(format!(
                        "cannot read weights {}: {e}",
                        path.display()
                    ))
                })?);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimal_spec_uses_synth_defaults() {
        let spec = ModelSpec::parse(
            "[model]\nname = \"m\"\nseed = 3\n",
            Path::new("."),
        )
        .unwrap();
        assert_eq!(spec.source, WeightSource::Seed(3));
        assert_eq!(spec.config, synth::default_config());
        let (blob, params) = spec.build_params().unwrap();
        let (blob2, params2) = synth::synth_params(3);
        assert_eq!(blob, blob2);
        assert_eq!(params, params2);
    }

    #[test]
    fn rejects_unknown_key_and_missing_name() {
        assert!(ModelSpec::parse("[model]\nname=\"m\"\nfoo=1\n",
                                 Path::new(".")).is_err());
        assert!(ModelSpec::parse("[model]\nseed=1\n", Path::new(".")).is_err());
        assert!(ModelSpec::parse("[model]\nname=\"a b\"\n", Path::new("."))
            .is_err());
    }

    #[test]
    fn rejects_weights_with_geometry() {
        let text = "[model]\nname=\"m\"\nweights=\"w.bin\"\n\
                    [geometry]\nheight = 12\n";
        assert!(ModelSpec::parse(text, Path::new(".")).is_err());
    }

    #[test]
    fn rejects_invalid_geometry() {
        // pool does not divide the image
        let text = "[model]\nname=\"m\"\n[head]\npool = 5\n";
        assert!(ModelSpec::parse(text, Path::new(".")).is_err());
        let text = "[model]\nname=\"m\"\n[lbp]\nwindow = 1\n";
        assert!(ModelSpec::parse(text, Path::new(".")).is_err());
    }

    #[test]
    fn fingerprint_tracks_every_field() {
        let a = ModelSpec::parse("[model]\nname=\"m\"\nseed=1\n",
                                 Path::new(".")).unwrap();
        let b = ModelSpec::parse("[model]\nname=\"m\"\nseed=2\n",
                                 Path::new(".")).unwrap();
        let c = ModelSpec::parse(
            "[model]\nname=\"m\"\nseed=1\n[lbp]\ne = 6\n",
            Path::new("."),
        )
        .unwrap();
        let fa = a.fingerprint().unwrap();
        assert_ne!(fa, b.fingerprint().unwrap());
        assert_ne!(fa, c.fingerprint().unwrap());
        assert_eq!(fa, a.fingerprint().unwrap());
    }
}
