//! PJRT runtime: load AOT-lowered HLO text artifacts and execute them.
//!
//! The request path is Rust-only: `make artifacts` (Python, build time)
//! lowers the JAX/Pallas stack to HLO **text** (`artifacts/*.hlo.txt` —
//! text, not serialized protos, because jax ≥ 0.5 emits 64-bit instruction
//! ids that xla_extension 0.5.1 rejects; the text parser reassigns them),
//! and this module compiles + runs them on the PJRT CPU client.
//!
//! The full-model artifacts take the MLP weights/affines as *parameters*
//! (large constants are elided by the HLO text printer), fed from the
//! parsed `NetParams` in the documented order:
//! `(images, w1, s1, b1, w2, s2, b2)`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::params::NetParams;

/// Manifest entry (artifacts/manifest.tsv).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    pub inputs: String,
    pub output: String,
}

/// Parse `manifest.tsv`.
pub fn read_manifest(dir: &Path) -> Result<Vec<ManifestEntry>> {
    let path = dir.join("manifest.tsv");
    let text = std::fs::read_to_string(&path).map_err(|e| {
        Error::Runtime(format!("cannot read {}: {e}", path.display()))
    })?;
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if i == 0 || line.trim().is_empty() {
            continue; // header
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 4 {
            return Err(Error::Runtime(format!(
                "manifest line {}: expected 4 columns, got {}",
                i + 1,
                cols.len()
            )));
        }
        out.push(ManifestEntry {
            name: cols[0].into(),
            file: cols[1].into(),
            inputs: cols[2].into(),
            output: cols[3].into(),
        });
    }
    Ok(out)
}

/// The runtime: one PJRT CPU client + a cache of compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at `artifacts_dir`.
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            artifacts_dir: artifacts_dir.into(),
            executables: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<name>.hlo.txt` (cached after the first call).
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(Error::Runtime(format!(
                "artifact {} not found — run `make artifacts`",
                path.display()
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| {
                Error::Runtime(format!("non-utf8 path {}", path.display()))
            })?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.executables.get(name).ok_or_else(|| {
            Error::Runtime(format!("executable {name:?} not loaded"))
        })
    }

    /// Execute a loaded artifact; unwraps the 1-tuple output literal.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let exe = self.exe(name)?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?)
    }

    /// Run the full Ap-LBP model artifact: images (B,H,W,C) f32 in [0,1]
    /// → logits (B, n_classes).
    pub fn run_aplbp(&self, name: &str, params: &NetParams, images: &[f32],
                     batch: usize) -> Result<Vec<Vec<f32>>> {
        let cfg = &params.config;
        let img_lit = literal_f32(
            images,
            &[batch, cfg.height, cfg.width, cfg.in_channels],
        )?;
        let mut inputs = vec![img_lit];
        inputs.extend(mlp_literals(params)?);
        let out = self.execute(name, &inputs)?;
        let flat = out.to_vec::<f32>()?;
        if flat.len() != batch * cfg.n_classes {
            return Err(Error::Runtime(format!(
                "model output has {} values, expected {}",
                flat.len(),
                batch * cfg.n_classes
            )));
        }
        Ok(flat.chunks(cfg.n_classes).map(|c| c.to_vec()).collect())
    }

    /// Run the LBP front-end artifact: images → pooled int32 features.
    pub fn run_features(&self, name: &str, params: &NetParams, images: &[f32],
                        batch: usize) -> Result<Vec<Vec<i32>>> {
        let cfg = &params.config;
        let img_lit = literal_f32(
            images,
            &[batch, cfg.height, cfg.width, cfg.in_channels],
        )?;
        let out = self.execute(name, &[img_lit])?;
        let flat = out.to_vec::<i32>()?;
        let d = cfg.feature_dim();
        if flat.len() != batch * d {
            return Err(Error::Runtime(format!(
                "features output has {} values, expected {}",
                flat.len(),
                batch * d
            )));
        }
        Ok(flat.chunks(d).map(|c| c.to_vec()).collect())
    }
}

/// Build an f32 literal with shape.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    if data.len() != n {
        return Err(Error::Runtime(format!(
            "literal data {} != shape product {n}",
            data.len()
        )));
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

/// Build an i32 literal with shape.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    if data.len() != n {
        return Err(Error::Runtime(format!(
            "literal data {} != shape product {n}",
            data.len()
        )));
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

/// The six MLP parameter literals in artifact order:
/// `(w1 s32[D,H], s1 f32[H], b1 f32[H], w2 s32[H,C], s2 f32[C], b2 f32[C])`.
pub fn mlp_literals(params: &NetParams) -> Result<Vec<xla::Literal>> {
    let m1 = &params.mlp1;
    let m2 = &params.mlp2;
    let w1: Vec<i32> = m1.w.iter().map(|&v| v as i32).collect();
    let w2: Vec<i32> = m2.w.iter().map(|&v| v as i32).collect();
    Ok(vec![
        literal_i32(&w1, &[m1.d, m1.o])?,
        literal_f32(&m1.scale, &[m1.o])?,
        literal_f32(&m1.bias, &[m1.o])?,
        literal_i32(&w2, &[m2.d, m2.o])?,
        literal_f32(&m2.scale, &[m2.o])?,
        literal_f32(&m2.bias, &[m2.o])?,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT-dependent tests live in rust/tests/ (they need artifacts);
    // here we cover the pure helpers.

    #[test]
    fn literal_shape_validation() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
        assert!(literal_i32(&[1, 2, 3], &[1, 3]).is_ok());
    }

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join(format!("nslbp-man-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "name\tfile\tinputs\toutput\na\ta.hlo.txt\tf32[1]\tf32[1]\n",
        )
        .unwrap();
        let m = read_manifest(&dir).unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].name, "a");
        std::fs::write(dir.join("manifest.tsv"), "h\nbad line\n").unwrap();
        assert!(read_manifest(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_artifact_reports_nicely() {
        let mut rt = Runtime::new("/nonexistent-dir").unwrap();
        let err = rt.load("nope").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
