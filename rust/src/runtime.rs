//! Artifact runtime: load AOT-lowered HLO text artifacts and execute them.
//!
//! The request path is Rust-only: `make artifacts` (Python, build time)
//! lowers the JAX/Pallas stack to HLO **text** (`artifacts/*.hlo.txt` —
//! text, not serialized protos, because jax ≥ 0.5 emits 64-bit instruction
//! ids that xla_extension 0.5.1 rejects; the text parser reassigns them),
//! and this module compiles + runs them on the PJRT CPU client.
//!
//! The PJRT backend itself lives behind the `pjrt` cargo feature because
//! the `xla` crate cannot be fetched in this offline environment.  The
//! default build keeps the full module surface — manifest parsing,
//! [`Literal`] construction/validation, artifact-presence checks — but
//! [`Runtime::load`] reports the backend as unavailable.  Callers that
//! want to degrade gracefully should consult [`pjrt_available`].
//!
//! The full-model artifacts take the MLP weights/affines as *parameters*
//! (large constants are elided by the HLO text printer), fed from the
//! parsed `NetParams` in the documented order:
//! `(images, w1, s1, b1, w2, s2, b2)`.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::params::NetParams;

/// Whether this build carries the PJRT/XLA execution backend.
pub const fn pjrt_available() -> bool {
    cfg!(feature = "pjrt")
}

/// Manifest entry (artifacts/manifest.tsv).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    pub inputs: String,
    pub output: String,
}

/// Parse `manifest.tsv`.  Tolerates CRLF line endings and stray
/// whitespace around columns — manifests written on Windows or
/// hand-edited must not break artifact resolution.
pub fn read_manifest(dir: &Path) -> Result<Vec<ManifestEntry>> {
    let path = dir.join("manifest.tsv");
    let text = std::fs::read_to_string(&path).map_err(|e| {
        Error::Runtime(format!("cannot read {}: {e}", path.display()))
    })?;
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        // `str::lines` strips `\r\n`, but not trailing spaces or tabs
        let line = line.trim_end();
        if i == 0 || line.is_empty() {
            continue; // header
        }
        let cols: Vec<&str> = line.split('\t').map(str::trim).collect();
        if cols.len() != 4 {
            return Err(Error::Runtime(format!(
                "manifest line {}: expected 4 columns, got {}",
                i + 1,
                cols.len()
            )));
        }
        out.push(ManifestEntry {
            name: cols[0].into(),
            file: cols[1].into(),
            inputs: cols[2].into(),
            output: cols[3].into(),
        });
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Literals
// ---------------------------------------------------------------------------

/// Host-side tensor literal handed to / returned from the backend.
///
/// Backend-neutral so the non-`pjrt` build keeps the full call surface;
/// the `pjrt` backend converts to/from `xla::Literal` at the boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<usize>,
}

#[derive(Clone, Debug, PartialEq)]
enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Literal {
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn len(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Extract the flat element buffer; errors on element-type mismatch.
    pub fn to_vec<T: LiteralElem>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }

    /// Borrow the flat f32 buffer without cloning (hot-path accessor);
    /// errors on element-type mismatch.
    pub fn as_f32_slice(&self) -> Result<&[f32]> {
        match &self.data {
            LiteralData::F32(v) => Ok(v),
            LiteralData::I32(_) => {
                Err(Error::Runtime("literal holds i32, asked for f32".into()))
            }
        }
    }

    /// Borrow the flat i32 buffer without cloning (hot-path accessor);
    /// errors on element-type mismatch.
    pub fn as_i32_slice(&self) -> Result<&[i32]> {
        match &self.data {
            LiteralData::I32(v) => Ok(v),
            LiteralData::F32(_) => {
                Err(Error::Runtime("literal holds f32, asked for i32".into()))
            }
        }
    }
}

/// Element types a [`Literal`] can hold.
pub trait LiteralElem: Sized {
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
}

impl LiteralElem for f32 {
    fn extract(lit: &Literal) -> Result<Vec<f32>> {
        match &lit.data {
            LiteralData::F32(v) => Ok(v.clone()),
            LiteralData::I32(_) => {
                Err(Error::Runtime("literal holds i32, asked for f32".into()))
            }
        }
    }
}

impl LiteralElem for i32 {
    fn extract(lit: &Literal) -> Result<Vec<i32>> {
        match &lit.data {
            LiteralData::I32(v) => Ok(v.clone()),
            LiteralData::F32(_) => {
                Err(Error::Runtime("literal holds f32, asked for i32".into()))
            }
        }
    }
}

fn check_shape(len: usize, dims: &[usize]) -> Result<()> {
    let n: usize = dims.iter().product();
    if len != n {
        return Err(Error::Runtime(format!(
            "literal data {len} != shape product {n}"
        )));
    }
    Ok(())
}

/// Build an f32 literal with shape.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    check_shape(data.len(), dims)?;
    Ok(Literal { data: LiteralData::F32(data.to_vec()), dims: dims.to_vec() })
}

/// Build an i32 literal with shape.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    check_shape(data.len(), dims)?;
    Ok(Literal { data: LiteralData::I32(data.to_vec()), dims: dims.to_vec() })
}

/// The six MLP parameter literals in artifact order:
/// `(w1 s32[D,H], s1 f32[H], b1 f32[H], w2 s32[H,C], s2 f32[C], b2 f32[C])`.
pub fn mlp_literals(params: &NetParams) -> Result<Vec<Literal>> {
    let m1 = &params.mlp1;
    let m2 = &params.mlp2;
    let w1: Vec<i32> = m1.w.iter().map(|&v| v as i32).collect();
    let w2: Vec<i32> = m2.w.iter().map(|&v| v as i32).collect();
    Ok(vec![
        literal_i32(&w1, &[m1.d, m1.o])?,
        literal_f32(&m1.scale, &[m1.o])?,
        literal_f32(&m1.bias, &[m1.o])?,
        literal_i32(&w2, &[m2.d, m2.o])?,
        literal_f32(&m2.scale, &[m2.o])?,
        literal_f32(&m2.bias, &[m2.o])?,
    ])
}

// ---------------------------------------------------------------------------
// Runtime
// ---------------------------------------------------------------------------

/// The runtime: one PJRT CPU client + a cache of compiled executables
/// (stubbed without the `pjrt` feature — see module docs).
pub struct Runtime {
    artifacts_dir: PathBuf,
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    executables: std::collections::HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a runtime rooted at `artifacts_dir`.
    pub fn new(artifacts_dir: impl Into<PathBuf>) -> Result<Self> {
        Ok(Self {
            artifacts_dir: artifacts_dir.into(),
            #[cfg(feature = "pjrt")]
            client: xla::PjRtClient::cpu()?,
            #[cfg(feature = "pjrt")]
            executables: std::collections::HashMap::new(),
        })
    }

    #[cfg(feature = "pjrt")]
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn platform(&self) -> String {
        "unavailable (built without the `pjrt` feature)".to_string()
    }

    /// Path to the `<name>.hlo.txt` artifact, erroring if the file is
    /// missing so the "run `make artifacts`" hint precedes backend errors.
    fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(Error::Runtime(format!(
                "artifact {} not found — run `make artifacts`",
                path.display()
            )));
        }
        Ok(path)
    }

    /// Load + compile `<name>.hlo.txt` (cached after the first call).
    #[cfg(feature = "pjrt")]
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.artifact_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| {
                Error::Runtime(format!("non-utf8 path {}", path.display()))
            })?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    /// Stub `load`: checks artifact presence, then reports the missing
    /// backend so callers can skip the golden path with a clear message.
    #[cfg(not(feature = "pjrt"))]
    pub fn load(&mut self, name: &str) -> Result<()> {
        self.artifact_path(name)?;
        Err(backend_unavailable())
    }

    /// Execute a loaded artifact; unwraps the 1-tuple output literal.
    #[cfg(feature = "pjrt")]
    pub fn execute(&self, name: &str, inputs: &[Literal]) -> Result<Literal> {
        let exe = self.executables.get(name).ok_or_else(|| {
            Error::Runtime(format!("executable {name:?} not loaded"))
        })?;
        let xla_inputs: Vec<xla::Literal> =
            inputs.iter().map(to_xla).collect::<Result<_>>()?;
        let result =
            exe.execute::<xla::Literal>(&xla_inputs)?[0][0].to_literal_sync()?;
        from_xla(result.to_tuple1()?)
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn execute(&self, _name: &str, _inputs: &[Literal]) -> Result<Literal> {
        Err(backend_unavailable())
    }

    /// Run the full Ap-LBP model artifact: images (B,H,W,C) f32 in [0,1]
    /// → logits (B, n_classes).
    pub fn run_aplbp(&self, name: &str, params: &NetParams, images: &[f32],
                     batch: usize) -> Result<Vec<Vec<f32>>> {
        let cfg = &params.config;
        let img_lit = literal_f32(
            images,
            &[batch, cfg.height, cfg.width, cfg.in_channels],
        )?;
        let mut inputs = vec![img_lit];
        inputs.extend(mlp_literals(params)?);
        let out = self.execute(name, &inputs)?;
        let flat = out.as_f32_slice()?;
        if flat.len() != batch * cfg.n_classes {
            return Err(Error::Runtime(format!(
                "model output has {} values, expected {}",
                flat.len(),
                batch * cfg.n_classes
            )));
        }
        Ok(flat.chunks(cfg.n_classes).map(|c| c.to_vec()).collect())
    }

    /// Run the LBP front-end artifact: images → pooled int32 features.
    pub fn run_features(&self, name: &str, params: &NetParams, images: &[f32],
                        batch: usize) -> Result<Vec<Vec<i32>>> {
        let cfg = &params.config;
        let img_lit = literal_f32(
            images,
            &[batch, cfg.height, cfg.width, cfg.in_channels],
        )?;
        let out = self.execute(name, &[img_lit])?;
        let flat = out.as_i32_slice()?;
        let d = cfg.feature_dim();
        if flat.len() != batch * d {
            return Err(Error::Runtime(format!(
                "features output has {} values, expected {}",
                flat.len(),
                batch * d
            )));
        }
        Ok(flat.chunks(d).map(|c| c.to_vec()).collect())
    }
}

#[cfg(not(feature = "pjrt"))]
fn backend_unavailable() -> Error {
    Error::Runtime(
        "PJRT backend not compiled into this build (rebuild with \
         `--features pjrt` and a vendored xla crate)"
            .into(),
    )
}

#[cfg(feature = "pjrt")]
fn to_xla(lit: &Literal) -> Result<xla::Literal> {
    let dims_i64: Vec<i64> = lit.dims.iter().map(|&d| d as i64).collect();
    let flat = match &lit.data {
        LiteralData::F32(v) => xla::Literal::vec1(v),
        LiteralData::I32(v) => xla::Literal::vec1(v),
    };
    Ok(flat.reshape(&dims_i64)?)
}

#[cfg(feature = "pjrt")]
fn from_xla(lit: xla::Literal) -> Result<Literal> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => literal_f32(&lit.to_vec::<f32>()?, &dims),
        xla::ElementType::S32 => literal_i32(&lit.to_vec::<i32>()?, &dims),
        other => Err(Error::Runtime(format!(
            "unsupported artifact output element type {other:?}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT-dependent tests live in rust/tests/ (they need artifacts);
    // here we cover the pure helpers.

    #[test]
    fn literal_shape_validation() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
        assert!(literal_i32(&[1, 2, 3], &[1, 3]).is_ok());
    }

    #[test]
    fn literal_typed_extraction() {
        let l = literal_i32(&[1, 2, 3], &[3]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
        assert!(l.to_vec::<f32>().is_err());
        assert_eq!(l.dims(), &[3]);
        assert!(!l.is_empty());
    }

    #[test]
    fn literal_borrowing_accessors() {
        let i = literal_i32(&[4, 5], &[2]).unwrap();
        assert_eq!(i.as_i32_slice().unwrap(), &[4, 5]);
        assert!(i.as_f32_slice().is_err());
        let f = literal_f32(&[1.5, 2.5], &[2]).unwrap();
        assert_eq!(f.as_f32_slice().unwrap(), &[1.5, 2.5]);
        assert!(f.as_i32_slice().is_err());
    }

    #[test]
    fn manifest_tolerates_crlf_and_stray_whitespace() {
        let dir = std::env::temp_dir()
            .join(format!("nslbp-man-crlf-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "name\tfile\tinputs\toutput\r\n\
             a\ta.hlo.txt \tf32[1]\tf32[1]\r\n\
             b \tb.hlo.txt\tf32[2]\tf32[2]\t\r\n",
        )
        .unwrap();
        let m = read_manifest(&dir).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].name, "a");
        assert_eq!(m[0].file, "a.hlo.txt");
        assert_eq!(m[1].name, "b");
        assert_eq!(m[1].file, "b.hlo.txt");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join(format!("nslbp-man-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "name\tfile\tinputs\toutput\na\ta.hlo.txt\tf32[1]\tf32[1]\n",
        )
        .unwrap();
        let m = read_manifest(&dir).unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].name, "a");
        std::fs::write(dir.join("manifest.tsv"), "h\nbad line\n").unwrap();
        assert!(read_manifest(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_artifact_reports_nicely() {
        let mut rt = Runtime::new("/nonexistent-dir").unwrap();
        let err = rt.load("nope").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
