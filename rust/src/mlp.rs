//! In-memory MLP acceleration (paper §5.2, Fig. 7).
//!
//! Every MLP layer is a 1×1-kernel convolution executed as bit-plane dot
//! products: with activations `I = Σ_m 2^m·C_m(I)` and weights
//! `W = Σ_n 2^n·C_n(W)`, the dot product is
//! `Σ_m Σ_n 2^{m+n} · bitcount(AND(C_n(W), C_m(I)))` [DoReFa, ref 45].
//!
//! Mapping: the bit-plane vectors `C_m(I)` live in the I region (32 rows)
//! and `C_n(W)` in the W region (32 rows) of a compute sub-array, 256
//! lanes per row; `NS-LBP_AND` (MAJ3 with the all-zero row) produces the
//! AND row in one cycle, then the DPU bit-counts, shifts by `m+n`, and
//! accumulates (Fig. 7 steps ③–④).  Signed weights are stored with a
//! `+2^{N−1}` offset and corrected with one row-sum per input vector —
//! identical to `python/compile/kernels/bitserial_mlp.py`.

use crate::dpu::Dpu;
use crate::error::{Error, Result};
use crate::isa::{Executor, IniValue, Instruction};
use crate::mapping::{LbpSubarrayMap, ResvRow};
use crate::params::MlpLayer;
use crate::sram::Region;

/// Prepacked, offset-stored weight bit-planes for one MLP layer.
///
/// The MLP weights are static across the life of an engine, yet the seed
/// hot path re-collected and re-transposed every weight column into the
/// W region for *every output neuron of every chunk of every frame*.
/// This packs them exactly once at engine build (mirroring PISA's
/// weights-resident-in-sensor design): for every `cols`-lane chunk of
/// the input dimension and every output neuron, the `w_bits` bit-plane
/// rows of the `+2^{N−1}` offset-stored unsigned weights are stored as
/// ready-to-write packed row words, so loading the W region is `w_bits`
/// bulk row writes ([`MlpSubarrayMap::load_weight_planes`]) with zero
/// per-call packing work.  Row contents — including the zero fill past a
/// short tail chunk — are bit-identical to what
/// [`MlpSubarrayMap::load_vector`] would have written.
#[derive(Clone, Debug)]
pub struct WeightPlanes {
    /// Bit width the planes were split at.
    pub w_bits: usize,
    /// Lanes per chunk (sub-array columns).
    pub cols: usize,
    /// Packed words per row (`cols / 64`).
    pub words: usize,
    /// Input-dimension chunks (`ceil(d / cols)`).
    pub chunks: usize,
    /// Output neurons.
    pub o: usize,
    /// Input dimension.
    pub d: usize,
    /// `[(chunk · o + out) · w_bits + n][words]` packed rows, flat.
    data: Vec<u64>,
}

impl WeightPlanes {
    /// Transpose `mlp`'s columns into offset-stored bit-plane rows for
    /// `cols`-lane chunks.
    pub fn pack(mlp: &MlpLayer, w_bits: usize, cols: usize) -> Result<Self> {
        if w_bits == 0 || w_bits > 8 {
            return Err(Error::Mapping(format!(
                "w_bits {w_bits} outside 1..=8"
            )));
        }
        if cols == 0 || cols % 64 != 0 {
            return Err(Error::Mapping(format!(
                "cols {cols} must be a non-zero multiple of 64"
            )));
        }
        if mlp.d == 0 || mlp.o == 0 {
            return Err(Error::Mapping("empty MLP layer".into()));
        }
        let words = cols / 64;
        let chunks = mlp.d.div_ceil(cols);
        let half = 1u8 << (w_bits - 1);
        let mut data = vec![0u64; chunks * mlp.o * w_bits * words];
        for ci in 0..chunks {
            let len = cols.min(mlp.d - ci * cols);
            for out in 0..mlp.o {
                let base = (ci * mlp.o + out) * w_bits * words;
                for di in 0..len {
                    let wu = (mlp.weight(ci * cols + di, out) as i16
                        + half as i16) as u8;
                    let word = di / 64;
                    let shift = (di % 64) as u32;
                    for n in 0..w_bits {
                        if (wu >> n) & 1 == 1 {
                            data[base + n * words + word] |= 1 << shift;
                        }
                    }
                }
            }
        }
        Ok(Self { w_bits, cols, words, chunks, o: mlp.o, d: mlp.d, data })
    }

    /// Lanes occupied by `chunk` (the tail chunk may be short).
    pub fn chunk_len(&self, chunk: usize) -> usize {
        self.cols.min(self.d - chunk * self.cols)
    }

    /// Packed row words of bit-plane `n` of output `out` in `chunk`.
    pub fn plane(&self, chunk: usize, out: usize, n: usize) -> Result<&[u64]> {
        if chunk >= self.chunks || out >= self.o || n >= self.w_bits {
            return Err(Error::Mapping(format!(
                "weight plane (chunk {chunk}, out {out}, n {n}) out of range"
            )));
        }
        let base = ((chunk * self.o + out) * self.w_bits + n) * self.words;
        Ok(&self.data[base..base + self.words])
    }

    /// Serialize for a `CompiledModel` artifact: six u32 shape fields,
    /// then the packed row words little-endian. Exact — `from_bytes`
    /// reproduces the struct bit for bit.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(24 + 8 + self.data.len() * 8);
        for v in [self.w_bits, self.cols, self.words, self.chunks, self.o,
                  self.d] {
            out.extend_from_slice(&(v as u32).to_le_bytes());
        }
        out.extend_from_slice(&(self.data.len() as u64).to_le_bytes());
        for &w in &self.data {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    /// Deserialize a `to_bytes` blob, re-validating every shape invariant
    /// `pack` guarantees so a corrupted artifact cannot smuggle in an
    /// inconsistent plane table.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let bad = |why: &str| Error::Mapping(format!("weight planes: {why}"));
        if bytes.len() < 32 {
            return Err(bad("truncated header"));
        }
        let u32_at = |i: usize| {
            u32::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap())
                as usize
        };
        let (w_bits, cols, words, chunks, o, d) =
            (u32_at(0), u32_at(1), u32_at(2), u32_at(3), u32_at(4), u32_at(5));
        if w_bits == 0 || w_bits > 8 {
            return Err(bad("w_bits outside 1..=8"));
        }
        if cols == 0 || cols % 64 != 0 || words != cols / 64 {
            return Err(bad("cols/words inconsistent"));
        }
        if d == 0 || o == 0 || chunks != d.div_ceil(cols) {
            return Err(bad("chunk count inconsistent with dimensions"));
        }
        let n_words =
            u64::from_le_bytes(bytes[24..32].try_into().unwrap()) as usize;
        if n_words != chunks * o * w_bits * words {
            return Err(bad("data length inconsistent with shape"));
        }
        if bytes.len() != 32 + n_words * 8 {
            return Err(bad("payload length mismatch"));
        }
        let data = bytes[32..]
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Self { w_bits, cols, words, chunks, o, d, data })
    }
}

/// Row-address helper for the W/I regions.
#[derive(Clone, Copy, Debug)]
pub struct MlpSubarrayMap {
    pub base: LbpSubarrayMap,
    pub act_bits: usize,
    pub w_bits: usize,
}

impl MlpSubarrayMap {
    pub fn new(base: LbpSubarrayMap, act_bits: usize, w_bits: usize) -> Result<Self> {
        if act_bits == 0 || w_bits == 0 {
            return Err(Error::Mapping("bit widths must be non-zero".into()));
        }
        let m = Self { base, act_bits, w_bits };
        if m.weight_slots() == 0 || m.input_slots() == 0 {
            return Err(Error::Mapping(
                "W/I regions too small for one bit-plane set".into(),
            ));
        }
        Ok(m)
    }

    /// Weight-vector slots resident in the W region (32/4 = 8 at defaults).
    pub fn weight_slots(&self) -> usize {
        self.base.layout.len(Region::Weight) / self.w_bits
    }

    pub fn input_slots(&self) -> usize {
        self.base.layout.len(Region::Input) / self.act_bits
    }

    /// Row of weight bit-plane `n` for `slot`.
    pub fn weight_plane_row(&self, slot: usize, n: usize) -> Result<usize> {
        if slot >= self.weight_slots() || n >= self.w_bits {
            return Err(Error::Mapping(format!(
                "weight plane (slot {slot}, n {n}) out of range"
            )));
        }
        self.base.layout.row(Region::Weight, slot * self.w_bits + n)
    }

    /// Row of input bit-plane `m` for `slot`.
    pub fn input_plane_row(&self, slot: usize, m: usize) -> Result<usize> {
        if slot >= self.input_slots() || m >= self.act_bits {
            return Err(Error::Mapping(format!(
                "input plane (slot {slot}, m {m}) out of range"
            )));
        }
        self.base.layout.row(Region::Input, slot * self.act_bits + m)
    }

    /// Load a ≤256-lane unsigned vector bit-plane-transposed into W or I.
    pub fn load_vector(&self, ex: &mut Executor<'_>, region: Region,
                       slot: usize, values: &[u8]) -> Result<()> {
        if values.len() > ex.array.cols() {
            return Err(Error::Mapping(format!(
                "{} lanes exceed {} columns",
                values.len(),
                ex.array.cols()
            )));
        }
        let (bits, row_of): (usize, &dyn Fn(usize) -> Result<usize>) = match region {
            Region::Weight => (self.w_bits, &|n| self.weight_plane_row(slot, n)),
            Region::Input => (self.act_bits, &|m| self.input_plane_row(slot, m)),
            other => {
                return Err(Error::Mapping(format!(
                    "load_vector targets W or I, not {other:?}"
                )))
            }
        };
        let words = ex.array.cols() / 64;
        // one staging row reused across bit-planes (hot path: a single
        // small allocation per load instead of one per plane, §Perf)
        let mut row = vec![0u64; words];
        for bit in 0..bits {
            row.fill(0);
            for (lane, &v) in values.iter().enumerate() {
                if (v >> bit) & 1 == 1 {
                    row[lane / 64] |= 1 << (lane % 64);
                }
            }
            ex.write_row(row_of(bit)?, &row)?;
        }
        Ok(())
    }

    /// Load the prepacked weight bit-planes of (`chunk`, `out`) into
    /// W-region `slot` — the bulk-write fast path replacing the seed's
    /// per-neuron collect + [`Self::load_vector`].  `w_bits` row writes,
    /// bit- and stat-identical to loading the same offset-stored column
    /// through `load_vector`.
    pub fn load_weight_planes(&self, ex: &mut Executor<'_>, slot: usize,
                              planes: &WeightPlanes, chunk: usize,
                              out: usize) -> Result<()> {
        if planes.w_bits != self.w_bits {
            return Err(Error::Mapping(format!(
                "planes packed at {} bits, map expects {}",
                planes.w_bits, self.w_bits
            )));
        }
        if planes.words != ex.array.cols() / 64 {
            return Err(Error::Mapping(format!(
                "planes packed for {} columns, sub-array has {}",
                planes.cols,
                ex.array.cols()
            )));
        }
        for n in 0..self.w_bits {
            ex.write_row(self.weight_plane_row(slot, n)?,
                         planes.plane(chunk, out, n)?)?;
        }
        Ok(())
    }

    /// In-memory unsigned bit-serial dot product over `lanes` lanes:
    /// `Σ_{m,n} 2^{m+n}·bitcount(AND(C_n(W), C_m(I)))`.
    ///
    /// One `NS-LBP_AND` (MAJ3 with all-zero) per (m, n) pair + one DPU
    /// bitcount/shift/add.  Allocation-free: the AND row is borrowed
    /// in place and the lane mask is applied inside the bit-counter
    /// ([`Dpu::bitcount_masked`]) instead of materializing a masked copy
    /// per plane pair (§Perf).
    pub fn dot_unsigned(&self, ex: &mut Executor<'_>, dpu: &mut Dpu,
                        w_slot: usize, i_slot: usize, lanes: usize) -> Result<i64> {
        let zero = self.base.resv(ResvRow::Zero);
        let scratch = self.base.resv(ResvRow::Scratch);
        ex.exec(Instruction::Ini { dest: zero, value: IniValue::Zeros })?;
        let mut acc = 0i64;
        for m in 0..self.act_bits {
            let i_row = self.input_plane_row(i_slot, m)?;
            for n in 0..self.w_bits {
                let w_row = self.weight_plane_row(w_slot, n)?;
                // NS-LBP_AND: MAJ3(w, i, 0)
                ex.exec(Instruction::Carry {
                    src1: w_row,
                    src2: i_row,
                    src3: zero,
                    dest: scratch,
                })?;
                ex.stats.record_ctrl_read();
                let row = ex.array.row_words(scratch)?;
                let count = dpu.bitcount_masked(row, lanes) as i64;
                let term = dpu.shift(count, (m + n) as u32);
                acc = dpu.add(acc, term);
            }
        }
        Ok(acc)
    }

    /// Signed dot product against offset-stored weights:
    /// `x·w = x·w_u − 2^{N−1}·Σx` (one extra row-sum via the DPU).
    pub fn dot_signed(&self, ex: &mut Executor<'_>, dpu: &mut Dpu,
                      w_slot: usize, i_slot: usize, lanes: usize,
                      x_rowsum: i64) -> Result<i64> {
        let raw = self.dot_unsigned(ex, dpu, w_slot, i_slot, lanes)?;
        let offset = 1i64 << (self.w_bits - 1);
        Ok(raw - offset * x_rowsum)
    }
}

/// Software reference for the bit-serial identity (used by tests and the
/// fast functional path).
pub fn dot_unsigned_ref(x: &[u8], w: &[u8]) -> i64 {
    x.iter().zip(w).map(|(&a, &b)| a as i64 * b as i64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sram::{RegionLayout, SubArray};

    fn maps() -> (LbpSubarrayMap, MlpSubarrayMap) {
        let base = LbpSubarrayMap::new(RegionLayout::default(), 8).unwrap();
        let mlp = MlpSubarrayMap::new(base, 4, 4).unwrap();
        (base, mlp)
    }

    #[test]
    fn slot_capacity_matches_paper_regions() {
        let (_, m) = maps();
        assert_eq!(m.weight_slots(), 8); // 32 rows / 4-bit planes
        assert_eq!(m.input_slots(), 8);
    }

    #[test]
    fn plane_rows_stay_inside_their_regions() {
        let (_, m) = maps();
        for slot in 0..m.weight_slots() {
            for n in 0..4 {
                let row = m.weight_plane_row(slot, n).unwrap();
                assert_eq!(m.base.layout.region_of(row), Some(Region::Weight));
            }
        }
        for slot in 0..m.input_slots() {
            for b in 0..4 {
                let row = m.input_plane_row(slot, b).unwrap();
                assert_eq!(m.base.layout.region_of(row), Some(Region::Input));
            }
        }
        assert!(m.weight_plane_row(8, 0).is_err());
        assert!(m.input_plane_row(0, 4).is_err());
    }

    #[test]
    fn inmemory_dot_matches_reference() {
        let (_, m) = maps();
        let mut rng = crate::rng::Xoshiro256::new(77);
        for lanes in [1usize, 63, 64, 100, 256] {
            let x: Vec<u8> = (0..lanes).map(|_| (rng.next_u64() % 16) as u8).collect();
            let w: Vec<u8> = (0..lanes).map(|_| (rng.next_u64() % 16) as u8).collect();
            let mut sa = SubArray::new(256, 256);
            let mut ex = Executor::new(&mut sa);
            m.load_vector(&mut ex, Region::Input, 0, &x).unwrap();
            m.load_vector(&mut ex, Region::Weight, 0, &w).unwrap();
            let mut dpu = Dpu::default();
            let got = m.dot_unsigned(&mut ex, &mut dpu, 0, 0, lanes).unwrap();
            assert_eq!(got, dot_unsigned_ref(&x, &w), "lanes={lanes}");
            assert_eq!(dpu.stats.bitcounts, 16); // 4x4 bit-plane pairs
        }
    }

    #[test]
    fn signed_dot_offset_correction() {
        let (_, m) = maps();
        let mut rng = crate::rng::Xoshiro256::new(3);
        let lanes = 200;
        let x: Vec<u8> = (0..lanes).map(|_| (rng.next_u64() % 16) as u8).collect();
        let w_signed: Vec<i8> =
            (0..lanes).map(|_| (rng.next_u64() % 16) as i8 - 8).collect();
        let w_u: Vec<u8> = w_signed.iter().map(|&v| (v + 8) as u8).collect();
        let mut sa = SubArray::new(256, 256);
        let mut ex = Executor::new(&mut sa);
        m.load_vector(&mut ex, Region::Input, 1, &x).unwrap();
        m.load_vector(&mut ex, Region::Weight, 2, &w_u).unwrap();
        let rowsum: i64 = x.iter().map(|&v| v as i64).sum();
        let mut dpu = Dpu::default();
        let got = m.dot_signed(&mut ex, &mut dpu, 2, 1, lanes, rowsum).unwrap();
        let want: i64 = x.iter().zip(&w_signed)
            .map(|(&a, &b)| a as i64 * b as i64).sum();
        assert_eq!(got, want);
    }

    #[test]
    fn stale_lanes_do_not_leak_into_dot() {
        // load 256 lanes into a slot, then a shorter vector; masked lanes
        // beyond the new length must not contribute.
        let (_, m) = maps();
        let mut sa = SubArray::new(256, 256);
        let mut ex = Executor::new(&mut sa);
        m.load_vector(&mut ex, Region::Input, 0, &[15u8; 256]).unwrap();
        m.load_vector(&mut ex, Region::Weight, 0, &[15u8; 256]).unwrap();
        let mut dpu = Dpu::default();
        let got = m.dot_unsigned(&mut ex, &mut dpu, 0, 0, 10).unwrap();
        assert_eq!(got, 10 * 15 * 15);
    }

    #[test]
    fn prepacked_weight_planes_match_load_vector_rows() {
        // loading via the prepacked planes must leave the W region (and
        // the executor stats) bit-identical to collecting the
        // offset-stored column and loading it through load_vector
        let (_, m) = maps();
        let mut rng = crate::rng::Xoshiro256::new(9);
        for d in [10usize, 256, 300, 511] {
            let o = 3;
            let layer = MlpLayer {
                d,
                o,
                w: (0..d * o).map(|_| (rng.next_u64() % 16) as i8 - 8)
                    .collect(),
                scale: vec![0.0; o],
                bias: vec![0.0; o],
            };
            let planes = WeightPlanes::pack(&layer, 4, 256).unwrap();
            assert_eq!(planes.chunks, d.div_ceil(256));
            for ci in 0..planes.chunks {
                let len = planes.chunk_len(ci);
                for out in 0..o {
                    let mut sa_a = SubArray::new(256, 256);
                    let mut ex_a = Executor::new(&mut sa_a);
                    m.load_weight_planes(&mut ex_a, 1, &planes, ci, out)
                        .unwrap();
                    let stats_a = ex_a.stats.clone();
                    let w_col: Vec<u8> = (0..len)
                        .map(|di| {
                            (layer.weight(ci * 256 + di, out) as i16 + 8)
                                as u8
                        })
                        .collect();
                    let mut sa_b = SubArray::new(256, 256);
                    let mut ex_b = Executor::new(&mut sa_b);
                    m.load_vector(&mut ex_b, Region::Weight, 1, &w_col)
                        .unwrap();
                    assert_eq!(ex_b.stats, stats_a, "stat parity");
                    for n in 0..4 {
                        let row = m.weight_plane_row(1, n).unwrap();
                        assert_eq!(sa_b.read_row(row).unwrap(),
                                   sa_a.read_row(row).unwrap(),
                                   "d={d} chunk={ci} out={out} plane={n}");
                    }
                }
            }
        }
        // dimension/bounds checks
        assert!(WeightPlanes::pack(
            &MlpLayer { d: 4, o: 1, w: vec![0; 4], scale: vec![0.0],
                        bias: vec![0.0] }, 0, 256).is_err());
        let layer = MlpLayer { d: 4, o: 1, w: vec![0; 4], scale: vec![0.0],
                               bias: vec![0.0] };
        let planes = WeightPlanes::pack(&layer, 4, 256).unwrap();
        assert!(planes.plane(1, 0, 0).is_err());
        assert!(planes.plane(0, 1, 0).is_err());
        assert!(planes.plane(0, 0, 4).is_err());
    }

    #[test]
    fn load_vector_rejects_wrong_region() {
        let (_, m) = maps();
        let mut sa = SubArray::new(256, 256);
        let mut ex = Executor::new(&mut sa);
        assert!(m
            .load_vector(&mut ex, Region::Pixel, 0, &[1, 2, 3])
            .is_err());
        assert!(m
            .load_vector(&mut ex, Region::Input, 0, &vec![0u8; 300])
            .is_err());
    }
}
