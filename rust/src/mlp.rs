//! In-memory MLP acceleration (paper §5.2, Fig. 7).
//!
//! Every MLP layer is a 1×1-kernel convolution executed as bit-plane dot
//! products: with activations `I = Σ_m 2^m·C_m(I)` and weights
//! `W = Σ_n 2^n·C_n(W)`, the dot product is
//! `Σ_m Σ_n 2^{m+n} · bitcount(AND(C_n(W), C_m(I)))` [DoReFa, ref 45].
//!
//! Mapping: the bit-plane vectors `C_m(I)` live in the I region (32 rows)
//! and `C_n(W)` in the W region (32 rows) of a compute sub-array, 256
//! lanes per row; `NS-LBP_AND` (MAJ3 with the all-zero row) produces the
//! AND row in one cycle, then the DPU bit-counts, shifts by `m+n`, and
//! accumulates (Fig. 7 steps ③–④).  Signed weights are stored with a
//! `+2^{N−1}` offset and corrected with one row-sum per input vector —
//! identical to `python/compile/kernels/bitserial_mlp.py`.

use crate::dpu::Dpu;
use crate::error::{Error, Result};
use crate::isa::{Executor, IniValue, Instruction};
use crate::mapping::{LbpSubarrayMap, ResvRow};
use crate::sram::Region;

/// Row-address helper for the W/I regions.
#[derive(Clone, Copy, Debug)]
pub struct MlpSubarrayMap {
    pub base: LbpSubarrayMap,
    pub act_bits: usize,
    pub w_bits: usize,
}

impl MlpSubarrayMap {
    pub fn new(base: LbpSubarrayMap, act_bits: usize, w_bits: usize) -> Result<Self> {
        if act_bits == 0 || w_bits == 0 {
            return Err(Error::Mapping("bit widths must be non-zero".into()));
        }
        let m = Self { base, act_bits, w_bits };
        if m.weight_slots() == 0 || m.input_slots() == 0 {
            return Err(Error::Mapping(
                "W/I regions too small for one bit-plane set".into(),
            ));
        }
        Ok(m)
    }

    /// Weight-vector slots resident in the W region (32/4 = 8 at defaults).
    pub fn weight_slots(&self) -> usize {
        self.base.layout.len(Region::Weight) / self.w_bits
    }

    pub fn input_slots(&self) -> usize {
        self.base.layout.len(Region::Input) / self.act_bits
    }

    /// Row of weight bit-plane `n` for `slot`.
    pub fn weight_plane_row(&self, slot: usize, n: usize) -> Result<usize> {
        if slot >= self.weight_slots() || n >= self.w_bits {
            return Err(Error::Mapping(format!(
                "weight plane (slot {slot}, n {n}) out of range"
            )));
        }
        self.base.layout.row(Region::Weight, slot * self.w_bits + n)
    }

    /// Row of input bit-plane `m` for `slot`.
    pub fn input_plane_row(&self, slot: usize, m: usize) -> Result<usize> {
        if slot >= self.input_slots() || m >= self.act_bits {
            return Err(Error::Mapping(format!(
                "input plane (slot {slot}, m {m}) out of range"
            )));
        }
        self.base.layout.row(Region::Input, slot * self.act_bits + m)
    }

    /// Load a ≤256-lane unsigned vector bit-plane-transposed into W or I.
    pub fn load_vector(&self, ex: &mut Executor<'_>, region: Region,
                       slot: usize, values: &[u8]) -> Result<()> {
        if values.len() > ex.array.cols() {
            return Err(Error::Mapping(format!(
                "{} lanes exceed {} columns",
                values.len(),
                ex.array.cols()
            )));
        }
        let (bits, row_of): (usize, &dyn Fn(usize) -> Result<usize>) = match region {
            Region::Weight => (self.w_bits, &|n| self.weight_plane_row(slot, n)),
            Region::Input => (self.act_bits, &|m| self.input_plane_row(slot, m)),
            other => {
                return Err(Error::Mapping(format!(
                    "load_vector targets W or I, not {other:?}"
                )))
            }
        };
        let words = ex.array.cols() / 64;
        for bit in 0..bits {
            let mut row = vec![0u64; words];
            for (lane, &v) in values.iter().enumerate() {
                if (v >> bit) & 1 == 1 {
                    row[lane / 64] |= 1 << (lane % 64);
                }
            }
            ex.array.write_row(row_of(bit)?, &row)?;
            ex.stats.row_writes += 1;
            ex.stats.cycles += 1;
        }
        Ok(())
    }

    /// In-memory unsigned bit-serial dot product over `lanes` lanes:
    /// `Σ_{m,n} 2^{m+n}·bitcount(AND(C_n(W), C_m(I)))`.
    ///
    /// One `NS-LBP_AND` (MAJ3 with all-zero) per (m, n) pair + one DPU
    /// bitcount/shift/add.
    pub fn dot_unsigned(&self, ex: &mut Executor<'_>, dpu: &mut Dpu,
                        w_slot: usize, i_slot: usize, lanes: usize) -> Result<i64> {
        let zero = self.base.resv(ResvRow::Zero);
        let scratch = self.base.resv(ResvRow::Scratch);
        ex.exec(Instruction::Ini { dest: zero, value: IniValue::Zeros })?;
        let words = lanes.div_ceil(64);
        let mut acc = 0i64;
        let mut lane_mask = vec![u64::MAX; words];
        if lanes % 64 != 0 {
            lane_mask[words - 1] = (1u64 << (lanes % 64)) - 1;
        }
        for m in 0..self.act_bits {
            let i_row = self.input_plane_row(i_slot, m)?;
            for n in 0..self.w_bits {
                let w_row = self.weight_plane_row(w_slot, n)?;
                // NS-LBP_AND: MAJ3(w, i, 0)
                ex.exec(Instruction::Carry {
                    src1: w_row,
                    src2: i_row,
                    src3: zero,
                    dest: scratch,
                })?;
                let row = ex.array.read_row(scratch)?;
                ex.stats.record_ctrl_read();
                let masked: Vec<u64> = row[..words]
                    .iter()
                    .zip(&lane_mask)
                    .map(|(&w, &m_)| w & m_)
                    .collect();
                let count = dpu.bitcount(&masked) as i64;
                let term = dpu.shift(count, (m + n) as u32);
                acc = dpu.add(acc, term);
            }
        }
        Ok(acc)
    }

    /// Signed dot product against offset-stored weights:
    /// `x·w = x·w_u − 2^{N−1}·Σx` (one extra row-sum via the DPU).
    pub fn dot_signed(&self, ex: &mut Executor<'_>, dpu: &mut Dpu,
                      w_slot: usize, i_slot: usize, lanes: usize,
                      x_rowsum: i64) -> Result<i64> {
        let raw = self.dot_unsigned(ex, dpu, w_slot, i_slot, lanes)?;
        let offset = 1i64 << (self.w_bits - 1);
        Ok(raw - offset * x_rowsum)
    }
}

/// Software reference for the bit-serial identity (used by tests and the
/// fast functional path).
pub fn dot_unsigned_ref(x: &[u8], w: &[u8]) -> i64 {
    x.iter().zip(w).map(|(&a, &b)| a as i64 * b as i64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sram::{RegionLayout, SubArray};

    fn maps() -> (LbpSubarrayMap, MlpSubarrayMap) {
        let base = LbpSubarrayMap::new(RegionLayout::default(), 8).unwrap();
        let mlp = MlpSubarrayMap::new(base, 4, 4).unwrap();
        (base, mlp)
    }

    #[test]
    fn slot_capacity_matches_paper_regions() {
        let (_, m) = maps();
        assert_eq!(m.weight_slots(), 8); // 32 rows / 4-bit planes
        assert_eq!(m.input_slots(), 8);
    }

    #[test]
    fn plane_rows_stay_inside_their_regions() {
        let (_, m) = maps();
        for slot in 0..m.weight_slots() {
            for n in 0..4 {
                let row = m.weight_plane_row(slot, n).unwrap();
                assert_eq!(m.base.layout.region_of(row), Some(Region::Weight));
            }
        }
        for slot in 0..m.input_slots() {
            for b in 0..4 {
                let row = m.input_plane_row(slot, b).unwrap();
                assert_eq!(m.base.layout.region_of(row), Some(Region::Input));
            }
        }
        assert!(m.weight_plane_row(8, 0).is_err());
        assert!(m.input_plane_row(0, 4).is_err());
    }

    #[test]
    fn inmemory_dot_matches_reference() {
        let (_, m) = maps();
        let mut rng = crate::rng::Xoshiro256::new(77);
        for lanes in [1usize, 63, 64, 100, 256] {
            let x: Vec<u8> = (0..lanes).map(|_| (rng.next_u64() % 16) as u8).collect();
            let w: Vec<u8> = (0..lanes).map(|_| (rng.next_u64() % 16) as u8).collect();
            let mut sa = SubArray::new(256, 256);
            let mut ex = Executor::new(&mut sa);
            m.load_vector(&mut ex, Region::Input, 0, &x).unwrap();
            m.load_vector(&mut ex, Region::Weight, 0, &w).unwrap();
            let mut dpu = Dpu::default();
            let got = m.dot_unsigned(&mut ex, &mut dpu, 0, 0, lanes).unwrap();
            assert_eq!(got, dot_unsigned_ref(&x, &w), "lanes={lanes}");
            assert_eq!(dpu.stats.bitcounts, 16); // 4x4 bit-plane pairs
        }
    }

    #[test]
    fn signed_dot_offset_correction() {
        let (_, m) = maps();
        let mut rng = crate::rng::Xoshiro256::new(3);
        let lanes = 200;
        let x: Vec<u8> = (0..lanes).map(|_| (rng.next_u64() % 16) as u8).collect();
        let w_signed: Vec<i8> =
            (0..lanes).map(|_| (rng.next_u64() % 16) as i8 - 8).collect();
        let w_u: Vec<u8> = w_signed.iter().map(|&v| (v + 8) as u8).collect();
        let mut sa = SubArray::new(256, 256);
        let mut ex = Executor::new(&mut sa);
        m.load_vector(&mut ex, Region::Input, 1, &x).unwrap();
        m.load_vector(&mut ex, Region::Weight, 2, &w_u).unwrap();
        let rowsum: i64 = x.iter().map(|&v| v as i64).sum();
        let mut dpu = Dpu::default();
        let got = m.dot_signed(&mut ex, &mut dpu, 2, 1, lanes, rowsum).unwrap();
        let want: i64 = x.iter().zip(&w_signed)
            .map(|(&a, &b)| a as i64 * b as i64).sum();
        assert_eq!(got, want);
    }

    #[test]
    fn stale_lanes_do_not_leak_into_dot() {
        // load 256 lanes into a slot, then a shorter vector; masked lanes
        // beyond the new length must not contribute.
        let (_, m) = maps();
        let mut sa = SubArray::new(256, 256);
        let mut ex = Executor::new(&mut sa);
        m.load_vector(&mut ex, Region::Input, 0, &[15u8; 256]).unwrap();
        m.load_vector(&mut ex, Region::Weight, 0, &[15u8; 256]).unwrap();
        let mut dpu = Dpu::default();
        let got = m.dot_unsigned(&mut ex, &mut dpu, 0, 0, 10).unwrap();
        assert_eq!(got, 10 * 15 * 15);
    }

    #[test]
    fn load_vector_rejects_wrong_region() {
        let (_, m) = maps();
        let mut sa = SubArray::new(256, 256);
        let mut ex = Executor::new(&mut sa);
        assert!(m
            .load_vector(&mut ex, Region::Pixel, 0, &[1, 2, 3])
            .is_err());
        assert!(m
            .load_vector(&mut ex, Region::Input, 0, &vec![0u8; 300])
            .is_err());
    }
}
