//! Bit-exact functional model of Ap-LBP inference, mirroring
//! `python/compile/model.py` integer-for-integer.
//!
//! Three implementations of the same network coexist and are cross-checked:
//!
//! 1. the AOT HLO artifact executed through PJRT ([`crate::runtime`]) — the
//!    JAX/Pallas golden model;
//! 2. **this module** — a plain-Rust functional model (fast path for the
//!    coordinator and sweeps);
//! 3. the architectural path — LBP comparisons via Algorithm 1 on the
//!    simulated sub-arrays and the MLP via in-memory AND/bitcount
//!    ([`crate::lbp`], [`crate::mlp`]), which also produces cycle/energy
//!    statistics.
//!
//! `rust/tests/golden_model.rs` asserts 1 == 2 on the artifact inputs;
//! unit tests here assert 2 == 3 on random images.

use crate::dpu::Dpu;
use crate::error::{Error, Result};
use crate::params::{LbpLayer, NetParams};

/// A u8 image tensor in HWC layout.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TensorU8 {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<u8>,
}

impl TensorU8 {
    pub fn zeros(h: usize, w: usize, c: usize) -> Self {
        Self { h, w, c, data: vec![0; h * w * c] }
    }

    /// Re-shape this tensor to `h × w × c`, zero-filled.  Reuses the
    /// existing allocation when the capacity suffices (hot path: scratch
    /// arenas re-shape instead of reallocating every frame/layer).
    pub fn reset(&mut self, h: usize, w: usize, c: usize) {
        self.h = h;
        self.w = w;
        self.c = c;
        self.data.clear();
        self.data.resize(h * w * c, 0);
    }

    #[inline]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> u8 {
        self.data[(y * self.w + x) * self.c + ch]
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: u8) {
        self.data[(y * self.w + x) * self.c + ch] = v;
    }

    /// Zero-padded fetch (paper's zero-padding, Fig. 3a).
    #[inline]
    pub fn get_padded(&self, y: i64, x: i64, ch: usize) -> u8 {
        if y < 0 || x < 0 || y >= self.h as i64 || x >= self.w as i64 {
            0
        } else {
            self.get(y as usize, x as usize, ch)
        }
    }
}

/// Sensor quantization: float [0,1] → u8 with `apx_pixel` LSBs masked
/// (mirrors `model.sensor_quantize`).
pub fn sensor_quantize(images: &[f32], apx_pixel: usize) -> Vec<u8> {
    let mask = 0xFFu8 ^ ((1u8 << apx_pixel).wrapping_sub(1));
    images
        .iter()
        .map(|&v| {
            let q = (v.clamp(0.0, 1.0) * 255.0 + 0.5).floor() as u32;
            (q.min(255) as u8) & mask
        })
        .collect()
}

/// LBP code of one output position for one kernel, with the PAC
/// skip-comparison (`apx_code` LSB samples never compared).
#[inline]
pub fn lbp_code(x: &TensorU8, layer: &LbpLayer, k: usize, y: usize, x_: usize,
                apx_code: usize) -> u32 {
    let pivot = x.get(y, x_, layer.pivot_ch[k] as usize);
    let mut code = 0u32;
    for (n, pt) in layer.offsets[k].iter().enumerate().skip(apx_code) {
        let v = x.get_padded(y as i64 + pt.dy as i64, x_ as i64 + pt.dx as i64,
                             pt.ch as usize);
        if v >= pivot {
            code |= 1 << n;
        }
    }
    code
}

/// Precomputed gather table for one LBP layer at a fixed input shape:
/// the `pad` border width plus per-kernel *linear* sample offsets into
/// the input tensor's data.  The layer patterns are static (LBP-Net's
/// pre-defined, non-learned kernels), so the table is built **once** at
/// engine construction ([`plan_layers`]) instead of on every
/// `lbp_layer_forward` call (hot path, §Perf — see EXPERIMENTS.md).
#[derive(Clone, Debug)]
pub struct LbpLayerPlan {
    /// Input width the offsets were linearized for.
    pub width: usize,
    /// Input channel count the offsets were linearized for.
    pub channels: usize,
    /// Border width that must take the zero-padded slow path.
    pub pad: usize,
    /// `[kernel][sample]` linear offsets into `x.data`.
    pub lin_offsets: Vec<Vec<isize>>,
}

impl LbpLayerPlan {
    /// Linearize `layer`'s sample pattern for a `width × channels` input.
    pub fn new(layer: &LbpLayer, width: usize, channels: usize) -> Self {
        let pad = layer
            .offsets
            .iter()
            .flatten()
            .map(|pt| pt.dy.unsigned_abs().max(pt.dx.unsigned_abs()) as usize)
            .max()
            .unwrap_or(0);
        let stride_y = (width * channels) as isize;
        let stride_c = channels as isize;
        let lin_offsets: Vec<Vec<isize>> = layer
            .offsets
            .iter()
            .map(|pts| {
                pts.iter()
                    .map(|pt| {
                        pt.dy as isize * stride_y + pt.dx as isize * stride_c
                            + pt.ch as isize
                    })
                    .collect()
            })
            .collect();
        Self { width, channels, pad, lin_offsets }
    }

    /// Serialize for a `CompiledModel` artifact: three u32 shape fields,
    /// kernel count, then per kernel a u32 count plus i64 offsets.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        for v in [self.width, self.channels, self.pad, self.lin_offsets.len()]
        {
            out.extend_from_slice(&(v as u32).to_le_bytes());
        }
        for pts in &self.lin_offsets {
            out.extend_from_slice(&(pts.len() as u32).to_le_bytes());
            for &off in pts {
                out.extend_from_slice(&(off as i64).to_le_bytes());
            }
        }
        out
    }

    /// Deserialize a `to_bytes` blob, consuming from the front of
    /// `bytes`; returns the plan and the number of bytes read.
    pub fn from_bytes(bytes: &[u8]) -> crate::error::Result<(Self, usize)> {
        use crate::error::Error;
        let bad = |why: &str| Error::Mapping(format!("lbp plan: {why}"));
        if bytes.len() < 16 {
            return Err(bad("truncated header"));
        }
        let u32_at = |i: usize| {
            u32::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap())
                as usize
        };
        let (width, channels, pad, kernels) =
            (u32_at(0), u32_at(1), u32_at(2), u32_at(3));
        if width == 0 || channels == 0 || kernels == 0 || kernels > 1 << 16 {
            return Err(bad("implausible shape"));
        }
        let mut pos = 16;
        let mut lin_offsets = Vec::with_capacity(kernels);
        for _ in 0..kernels {
            if bytes.len() < pos + 4 {
                return Err(bad("truncated kernel header"));
            }
            let n = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap())
                as usize;
            pos += 4;
            if n > 1 << 16 || bytes.len() < pos + n * 8 {
                return Err(bad("truncated offsets"));
            }
            let pts = bytes[pos..pos + n * 8]
                .chunks_exact(8)
                .map(|c| i64::from_le_bytes(c.try_into().unwrap()) as isize)
                .collect();
            pos += n * 8;
            lin_offsets.push(pts);
        }
        Ok((Self { width, channels, pad, lin_offsets }, pos))
    }
}

/// One gather plan per LBP layer of `params` (the joint concat grows the
/// channel count layer by layer, so each layer gets its own table).
pub fn plan_layers(params: &NetParams) -> Vec<LbpLayerPlan> {
    let chs = params.config.channels_after();
    params
        .lbp_layers
        .iter()
        .zip(&chs)
        .map(|(layer, &c)| LbpLayerPlan::new(layer, params.config.width, c))
        .collect()
}

/// One LBP layer: K encoded channels through shifted-ReLU, joint-concat
/// with the input (mirrors `model.lbp_layer_forward`).
///
/// Hot path (§Perf): interior pixels take a branch-free path with
/// precomputed linear offsets; only the `pad`-wide border pays the
/// zero-padding bounds checks.  This convenience wrapper builds the
/// gather plan per call; steady-state callers hold a [`LbpLayerPlan`]
/// and a reusable output tensor and use [`lbp_layer_forward_into`].
pub fn lbp_layer_forward(x: &TensorU8, layer: &LbpLayer, e: usize,
                         apx_code: usize, dpu: &mut Dpu) -> TensorU8 {
    let plan = LbpLayerPlan::new(layer, x.w, x.c);
    let mut out = TensorU8::zeros(0, 0, 0);
    lbp_layer_forward_into(x, layer, &plan, e, apx_code, dpu, &mut out);
    out
}

/// Allocation-free [`lbp_layer_forward`]: the gather table comes from a
/// prebuilt [`LbpLayerPlan`] and the output is written into a reusable
/// tensor (re-shaped in place, so a warm buffer never reallocates).
/// Bit-identical to the wrapper.
pub fn lbp_layer_forward_into(x: &TensorU8, layer: &LbpLayer,
                              plan: &LbpLayerPlan, e: usize, apx_code: usize,
                              dpu: &mut Dpu, out: &mut TensorU8) {
    debug_assert_eq!(plan.width, x.w, "plan linearized for another width");
    debug_assert_eq!(plan.channels, x.c, "plan linearized for another depth");
    let k_n = layer.offsets.len();
    out.reset(x.h, x.w, x.c + k_n);
    // pass-through of the joint input channels (row-contiguous copy)
    for y in 0..x.h {
        for x_ in 0..x.w {
            for ch in 0..x.c {
                out.set(y, x_, ch, x.get(y, x_, ch));
            }
        }
    }
    let pad = plan.pad;
    for y in 0..x.h {
        let interior_y = y >= pad && y + pad < x.h;
        for x_ in 0..x.w {
            let interior = interior_y && x_ >= pad && x_ + pad < x.w;
            let base = ((y * x.w + x_) * x.c) as isize;
            for k in 0..k_n {
                let code = if interior {
                    let pivot = x.data[(base + layer.pivot_ch[k] as isize) as usize];
                    let mut code = 0u32;
                    for (n, &off) in plan.lin_offsets[k].iter().enumerate().skip(apx_code) {
                        let v = x.data[(base + off) as usize];
                        code |= ((v >= pivot) as u32) << n;
                    }
                    code
                } else {
                    lbp_code(x, layer, k, y, x_, apx_code)
                };
                out.set(y, x_, x.c + k, dpu.shifted_relu_u8(code, e as u32));
            }
        }
    }
}

/// Full LBP front-end: u8 image → pooled act_bits features
/// (mirrors `model.forward_lbp` after sensor quantization).
pub fn forward_lbp(params: &NetParams, image: &TensorU8,
                   dpu: &mut Dpu) -> Result<Vec<u8>> {
    let cfg = &params.config;
    if image.h != cfg.height || image.w != cfg.width || image.c != cfg.in_channels {
        return Err(Error::Mapping(format!(
            "image {}x{}x{} vs config {}x{}x{}",
            image.h, image.w, image.c, cfg.height, cfg.width, cfg.in_channels
        )));
    }
    let mut x = image.clone();
    for layer in &params.lbp_layers {
        x = lbp_layer_forward(&x, layer, cfg.e, cfg.apx_code, dpu);
    }
    pool_quantize(&x, cfg.pool, cfg.act_bits, dpu)
}

/// Integer average pooling + exact requantize to `act_bits` — the tail
/// of [`forward_lbp`], shared with the architectural backend so both
/// paths run the identical DPU math.  The returned feature vector is the
/// only allocation (it escapes into the caller's output).
pub fn pool_quantize(x: &TensorU8, pool: usize, act_bits: usize,
                     dpu: &mut Dpu) -> Result<Vec<u8>> {
    let s = pool;
    let vmax = (255 * s * s) as u32;
    let (ph, pw) = (x.h / s, x.w / s);
    let mut feats = Vec::with_capacity(ph * pw * x.c);
    for py in 0..ph {
        for px in 0..pw {
            for ch in 0..x.c {
                let mut sum = 0u32;
                for dy in 0..s {
                    for dx in 0..s {
                        sum += x.get(py * s + dy, px * s + dx, ch) as u32;
                    }
                }
                feats.push(dpu.quantize_pooled(sum, vmax, act_bits as u32)?);
            }
        }
    }
    Ok(feats)
}

/// Integer matmul `feats (u8[d]) × w (i8[d,o]) → i64[o]` — input-major
/// iteration so every weight access is contiguous (hot path, §Perf);
/// zero activations (common after ReLU/quantize) skip their row entirely.
pub fn int_matmul(feats: &[u8], mlp: &crate::params::MlpLayer) -> Vec<i64> {
    let mut acc = Vec::new();
    int_matmul_into(feats, mlp, &mut acc);
    acc
}

/// Allocation-free [`int_matmul`]: the accumulator is a caller-owned
/// buffer (cleared and refilled), so the architectural backend's
/// per-layer cross-check reuses one arena vector instead of allocating
/// per call.  Bit-identical to [`int_matmul`]: the i64 sum is truncated
/// through i32 at the end, matching the historical i32 accumulator's
/// mod-2^32 arithmetic exactly.
pub fn int_matmul_into(feats: &[u8], mlp: &crate::params::MlpLayer,
                       acc: &mut Vec<i64>) {
    debug_assert_eq!(feats.len(), mlp.d);
    acc.clear();
    acc.resize(mlp.o, 0);
    for (di, &f) in feats.iter().enumerate() {
        if f == 0 {
            continue;
        }
        let f = f as i64;
        let row = &mlp.w[di * mlp.o..(di + 1) * mlp.o];
        for (a, &w) in acc.iter_mut().zip(row) {
            *a += f * w as i64;
        }
    }
    for a in acc.iter_mut() {
        *a = *a as i32 as i64;
    }
}

/// Weight-stationary batched matmul: one pass over the weight matrix
/// serves every frame in the batch, so `w` streams through the cache
/// once per batch instead of once per frame.  Bit-identical to
/// [`int_matmul`] per frame (each accumulator sees the same additions in
/// the same `di` order).  Generic over the per-frame container so
/// callers pass `&[Vec<u8>]` or `&[&[u8]]` directly — no borrow vector
/// needs to be collected first (§Perf).
pub fn int_matmul_batch<S: AsRef<[u8]>>(batch: &[S],
                                        mlp: &crate::params::MlpLayer)
                                        -> Vec<Vec<i64>> {
    let mut accs = vec![vec![0i32; mlp.o]; batch.len()];
    for di in 0..mlp.d {
        let row = &mlp.w[di * mlp.o..(di + 1) * mlp.o];
        for (feats, acc) in batch.iter().zip(accs.iter_mut()) {
            let feats = feats.as_ref();
            debug_assert_eq!(feats.len(), mlp.d);
            let f = feats[di];
            if f == 0 {
                continue;
            }
            let f = f as i32;
            for (a, &w) in acc.iter_mut().zip(row) {
                *a += f * w as i32;
            }
        }
    }
    accs.into_iter()
        .map(|acc| acc.into_iter().map(|v| v as i64).collect())
        .collect()
}

/// Batched 2-layer MLP: the matmuls run weight-stationary over the whole
/// batch ([`int_matmul_batch`]); activation/affine run per frame against
/// that frame's own DPU so per-frame activity counters stay identical to
/// the [`mlp_forward`] path.  `dpus.len()` must equal `feats_batch.len()`.
pub fn mlp_forward_batch(params: &NetParams, feats_batch: &[Vec<u8>],
                         dpus: &mut [Dpu]) -> Result<Vec<Vec<f32>>> {
    assert_eq!(feats_batch.len(), dpus.len(), "one DPU per frame");
    let cfg = &params.config;
    for feats in feats_batch {
        if feats.len() != params.mlp1.d {
            return Err(Error::Mapping(format!(
                "feature dim {} != {}",
                feats.len(),
                params.mlp1.d
            )));
        }
    }
    let m1 = &params.mlp1;
    let acc1 = int_matmul_batch(feats_batch, m1);
    let hidden_q: Vec<Vec<u8>> = acc1
        .iter()
        .zip(dpus.iter_mut())
        .map(|(acc, dpu)| {
            acc.iter()
                .enumerate()
                .map(|(o, &h)| dpu.activation(h, m1.scale[o], m1.bias[o],
                                              cfg.act_bits as u32))
                .collect()
        })
        .collect();
    let m2 = &params.mlp2;
    let acc2 = int_matmul_batch(&hidden_q, m2);
    Ok(acc2
        .iter()
        .zip(dpus.iter_mut())
        .map(|(acc, dpu)| {
            acc.iter()
                .enumerate()
                .map(|(o, &h)| dpu.affine(h, m2.scale[o], m2.bias[o]))
                .collect()
        })
        .collect())
}

/// Quantized 2-layer MLP → logits (mirrors `model.mlp_forward`).
pub fn mlp_forward(params: &NetParams, feats: &[u8], dpu: &mut Dpu) -> Result<Vec<f32>> {
    let cfg = &params.config;
    if feats.len() != params.mlp1.d {
        return Err(Error::Mapping(format!(
            "feature dim {} != {}",
            feats.len(),
            params.mlp1.d
        )));
    }
    // layer 1: integer matmul + activation (ReLU-clip + requantize)
    let m1 = &params.mlp1;
    let acc1 = int_matmul(feats, m1);
    let hidden_q: Vec<u8> = acc1
        .iter()
        .enumerate()
        .map(|(o, &h)| dpu.activation(h, m1.scale[o], m1.bias[o],
                                      cfg.act_bits as u32))
        .collect();
    // layer 2: integer matmul + affine → logits
    let m2 = &params.mlp2;
    let acc2 = int_matmul(&hidden_q, m2);
    Ok(acc2
        .iter()
        .enumerate()
        .map(|(o, &h)| dpu.affine(h, m2.scale[o], m2.bias[o]))
        .collect())
}

/// End-to-end: float image [0,1] HWC → logits.
pub fn apply(params: &NetParams, image_f32: &[f32], dpu: &mut Dpu) -> Result<Vec<f32>> {
    let cfg = &params.config;
    let expected = cfg.height * cfg.width * cfg.in_channels;
    if image_f32.len() != expected {
        return Err(Error::Mapping(format!(
            "image has {} values, expected {expected}",
            image_f32.len()
        )));
    }
    let q = sensor_quantize(image_f32, cfg.apx_pixel);
    let image = TensorU8 { h: cfg.height, w: cfg.width, c: cfg.in_channels,
                           data: q };
    let feats = forward_lbp(params, &image, dpu)?;
    mlp_forward(params, &feats, dpu)
}

/// Argmax helper for classification.
pub fn argmax(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::synth::synth_params;
    use crate::rng::Xoshiro256;

    fn image(params: &NetParams, seed: u64) -> Vec<f32> {
        let cfg = &params.config;
        let mut rng = Xoshiro256::new(seed);
        (0..cfg.height * cfg.width * cfg.in_channels)
            .map(|_| rng.next_f64() as f32)
            .collect()
    }

    #[test]
    fn shapes_flow_through() {
        let (_, params) = synth_params(1);
        let mut dpu = Dpu::default();
        let logits = apply(&params, &image(&params, 2), &mut dpu).unwrap();
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
        assert!(argmax(&logits) < 10);
    }

    #[test]
    fn deterministic() {
        let (_, params) = synth_params(1);
        let img = image(&params, 3);
        let a = apply(&params, &img, &mut Dpu::default()).unwrap();
        let b = apply(&params, &img, &mut Dpu::default()).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn rejects_wrong_image_size() {
        let (_, params) = synth_params(1);
        assert!(apply(&params, &[0.0; 3], &mut Dpu::default()).is_err());
    }

    #[test]
    fn batched_mlp_matches_per_frame_exactly() {
        let (_, params) = synth_params(1);
        let cfg = params.config;
        let mut rng = Xoshiro256::new(11);
        let feats_batch: Vec<Vec<u8>> = (0..5)
            .map(|_| {
                (0..params.mlp1.d)
                    .map(|_| rng.below(1u64 << cfg.act_bits) as u8)
                    .collect()
            })
            .collect();
        // per-frame reference
        let mut ref_dpus: Vec<Dpu> = (0..5).map(|_| Dpu::default()).collect();
        let reference: Vec<Vec<f32>> = feats_batch
            .iter()
            .zip(ref_dpus.iter_mut())
            .map(|(f, dpu)| mlp_forward(&params, f, dpu).unwrap())
            .collect();
        // weight-stationary batch path
        let mut dpus: Vec<Dpu> = (0..5).map(|_| Dpu::default()).collect();
        let batched =
            mlp_forward_batch(&params, &feats_batch, &mut dpus).unwrap();
        assert_eq!(batched, reference);
        // ... with identical per-frame DPU activity counters
        for (a, b) in dpus.iter().zip(&ref_dpus) {
            assert_eq!(a.stats, b.stats);
        }
        // raw integer accumulators agree too
        let views: Vec<&[u8]> =
            feats_batch.iter().map(|f| f.as_slice()).collect();
        for (batch_acc, feats) in
            int_matmul_batch(&views, &params.mlp1).iter().zip(&feats_batch)
        {
            assert_eq!(*batch_acc, int_matmul(feats, &params.mlp1));
        }
    }

    #[test]
    fn sensor_quantize_matches_python() {
        // floor(x*255+0.5) then mask
        let xs = [0.0f32, 1.0, 0.5, 0.123, 0.999, -0.5, 2.0];
        let q = sensor_quantize(&xs, 0);
        assert_eq!(q, vec![0, 255, 128, 31, 255, 0, 255]);
        let q2 = sensor_quantize(&xs, 2);
        for (a, b) in q.iter().zip(&q2) {
            assert_eq!(a & 0xFC, *b);
        }
    }

    #[test]
    fn lbp_code_respects_apx_and_padding() {
        let (_, params) = synth_params(7);
        let cfg = &params.config;
        let mut img = TensorU8::zeros(cfg.height, cfg.width, cfg.in_channels);
        // uniform 100s: every in-bounds neighbor == pivot -> bit 1;
        // out-of-bounds neighbors are 0 < pivot -> bit 0.
        for v in img.data.iter_mut() {
            *v = 100;
        }
        let layer = &params.lbp_layers[0];
        // interior pixel: all e bits set (>= on equality)
        let code = lbp_code(&img, layer, 0, 5, 5, 0);
        assert_eq!(code, 0xFF);
        // apx=2 masks the two LSB samples
        let code2 = lbp_code(&img, layer, 0, 5, 5, 2);
        assert_eq!(code2, 0xFC);
        // corner pixel: some neighbors padded to 0 -> their bits clear
        let corner = lbp_code(&img, layer, 0, 0, 0, 0);
        assert!(corner < 0xFF);
    }

    #[test]
    fn joint_concat_grows_channels() {
        let (_, params) = synth_params(9);
        let cfg = &params.config;
        let img = TensorU8::zeros(cfg.height, cfg.width, cfg.in_channels);
        let mut dpu = Dpu::default();
        let out = lbp_layer_forward(&img, &params.lbp_layers[0], cfg.e,
                                    cfg.apx_code, &mut dpu);
        assert_eq!(out.c, cfg.in_channels + cfg.kernels_per_layer);
        // pass-through of the input channels
        for y in 0..out.h {
            for x in 0..out.w {
                assert_eq!(out.get(y, x, 0), img.get(y, x, 0));
            }
        }
    }

    #[test]
    fn features_bounded_by_act_bits() {
        let (_, params) = synth_params(11);
        let mut dpu = Dpu::default();
        let img_f = image(&params, 5);
        let q = sensor_quantize(&img_f, 0);
        let cfg = &params.config;
        let img = TensorU8 { h: cfg.height, w: cfg.width, c: cfg.in_channels,
                             data: q };
        let feats = forward_lbp(&params, &img, &mut dpu).unwrap();
        assert_eq!(feats.len(), cfg.feature_dim());
        let qmax = (1u8 << cfg.act_bits) - 1;
        assert!(feats.iter().all(|&f| f <= qmax));
    }

    /// The precomputed-plan `_into` variants are bit-identical to the
    /// per-call wrappers, including on reused (warm) output buffers.
    #[test]
    fn plan_and_into_variants_match_wrappers() {
        let (_, params) = synth_params(21);
        let cfg = &params.config;
        let plans = plan_layers(&params);
        assert_eq!(plans.len(), params.lbp_layers.len());
        let mut rng = Xoshiro256::new(23);
        let mut warm = TensorU8::zeros(0, 0, 0);
        for round in 0..3 {
            let img = TensorU8 {
                h: cfg.height,
                w: cfg.width,
                c: cfg.in_channels,
                data: (0..cfg.height * cfg.width * cfg.in_channels)
                    .map(|_| rng.next_u64() as u8)
                    .collect(),
            };
            let layer = &params.lbp_layers[0];
            let mut dpu_a = Dpu::default();
            let want = lbp_layer_forward(&img, layer, cfg.e, cfg.apx_code,
                                         &mut dpu_a);
            let mut dpu_b = Dpu::default();
            lbp_layer_forward_into(&img, layer, &plans[0], cfg.e,
                                   cfg.apx_code, &mut dpu_b, &mut warm);
            assert_eq!(warm, want, "round {round}");
            assert_eq!(dpu_a.stats, dpu_b.stats);
        }
        // int_matmul_into on a reused accumulator == int_matmul
        let feats: Vec<u8> = (0..params.mlp1.d)
            .map(|_| rng.below(1u64 << cfg.act_bits) as u8)
            .collect();
        let mut acc = vec![99i64; 3]; // stale contents must be cleared
        int_matmul_into(&feats, &params.mlp1, &mut acc);
        assert_eq!(acc, int_matmul(&feats, &params.mlp1));
    }

    /// Functional path == architectural path (ISA-simulated Algorithm 1 +
    /// in-memory MLP) on the LBP comparisons of the first layer.
    #[test]
    fn functional_equals_architectural_compare() {
        use crate::isa::Executor;
        use crate::mapping::LbpSubarrayMap;
        use crate::sram::{RegionLayout, SubArray};

        let (_, params) = synth_params(13);
        let cfg = &params.config;
        let img_f = image(&params, 21);
        let q = sensor_quantize(&img_f, cfg.apx_pixel);
        let img = TensorU8 { h: cfg.height, w: cfg.width, c: cfg.in_channels,
                             data: q };
        let layer = &params.lbp_layers[0];

        // functional codes for kernel 0, row 3
        let mut pairs = Vec::new();
        let mut want_bits = Vec::new();
        let y = 3usize;
        for x_ in 0..cfg.width {
            let pivot = img.get(y, x_, layer.pivot_ch[0] as usize);
            for pt in &layer.offsets[0] {
                let v = img.get_padded(y as i64 + pt.dy as i64,
                                       x_ as i64 + pt.dx as i64,
                                       pt.ch as usize);
                pairs.push((v, pivot));
                want_bits.push(v >= pivot);
            }
        }
        // architectural: Algorithm 1 over the mapped sub-array
        let map = LbpSubarrayMap::new(RegionLayout::default(), 8).unwrap();
        let mut sa = SubArray::new(256, 256);
        let mut got_bits = Vec::new();
        for chunk in pairs.chunks(256) {
            map.load_lanes(&mut sa, 0, chunk).unwrap();
            let mut ex = Executor::new(&mut sa);
            let out = crate::lbp::parallel_compare(&mut ex, &map, 0,
                                                   chunk.len(), 0, false)
                .unwrap();
            got_bits.extend(out.bits);
        }
        assert_eq!(got_bits, want_bits);
    }
}
