//! Crate-wide error type (hand-rolled — thiserror is unavailable offline).

use std::fmt;

/// Errors surfaced by the NS-LBP runtime and simulator.
#[derive(Debug)]
pub enum Error {
    /// Malformed or out-of-range configuration value.
    Config(String),

    /// CLI usage error (unknown flag, missing value, bad subcommand).
    Usage(String),

    /// Parameter file (`*.params.bin`) parse failure.
    Params(String),

    /// An ISA-level fault: bad opcode operands, out-of-range row address,
    /// region protection violation.
    Isa(String),

    /// Mapping failure: workload does not fit the sub-array regions.
    Mapping(String),

    /// The analog circuit model was driven outside its calibrated envelope.
    Circuit(String),

    /// PJRT / XLA runtime failure (artifact load, compile, execute).
    Runtime(String),

    /// Coordinator pipeline failure (worker panicked, channel closed).
    Coordinator(String),

    /// Engine-layer failure (backend unavailable, bad selection,
    /// cross-check wiring fault, frame/network shape mismatch).
    Engine(String),

    /// Serving-layer failure (admission rejection, drain fault, dead shard).
    Serve(String),

    /// A serve request was shed without being inferred: displaced by
    /// drop-oldest admission or expired past its per-request deadline.
    /// A distinct variant so callers can tell expected load-shedding
    /// apart from real failures without parsing message text.
    Dropped(String),

    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Usage(m) => write!(f, "usage error: {m}"),
            Error::Params(m) => write!(f, "params parse error: {m}"),
            Error::Isa(m) => write!(f, "isa fault: {m}"),
            Error::Mapping(m) => write!(f, "mapping error: {m}"),
            Error::Circuit(m) => write!(f, "circuit model error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Engine(m) => write!(f, "engine error: {m}"),
            Error::Serve(m) => write!(f, "serve error: {m}"),
            Error::Dropped(m) => write!(f, "request dropped: {m}"),
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_match_variants() {
        assert!(Error::Config("x".into()).to_string().starts_with("config error"));
        assert!(Error::Serve("x".into()).to_string().starts_with("serve error"));
        assert!(Error::Dropped("x".into()).to_string().starts_with("request dropped"));
        assert!(Error::Runtime("x".into()).to_string().starts_with("runtime error"));
    }

    #[test]
    fn io_error_is_transparent_with_source() {
        use std::error::Error as _;
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e = Error::from(io);
        assert!(e.to_string().contains("gone"));
        assert!(e.source().is_some());
    }
}
