//! Crate-wide error type.

use thiserror::Error;

/// Errors surfaced by the NS-LBP runtime and simulator.
#[derive(Error, Debug)]
pub enum Error {
    /// Malformed or out-of-range configuration value.
    #[error("config error: {0}")]
    Config(String),

    /// CLI usage error (unknown flag, missing value, bad subcommand).
    #[error("usage error: {0}")]
    Usage(String),

    /// Parameter file (`*.params.bin`) parse failure.
    #[error("params parse error: {0}")]
    Params(String),

    /// An ISA-level fault: bad opcode operands, out-of-range row address,
    /// region protection violation.
    #[error("isa fault: {0}")]
    Isa(String),

    /// Mapping failure: workload does not fit the sub-array regions.
    #[error("mapping error: {0}")]
    Mapping(String),

    /// The analog circuit model was driven outside its calibrated envelope.
    #[error("circuit model error: {0}")]
    Circuit(String),

    /// PJRT / XLA runtime failure (artifact load, compile, execute).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator pipeline failure (worker panicked, channel closed).
    #[error("coordinator error: {0}")]
    Coordinator(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
