//! Deterministic PRNG substrate (no `rand` crate offline).
//!
//! [`Xoshiro256`] implements xoshiro256++ — a small, fast, well-studied
//! generator, seeded via SplitMix64 so that any `u64` seed produces a
//! well-mixed state.  Gaussian deviates (for the Monte-Carlo circuit model)
//! use the polar Box–Muller method with a cached spare.
//!
//! Everything in the simulator that needs randomness takes an explicit
//! `&mut Xoshiro256`, so whole-system runs are reproducible from one seed —
//! a requirement for the paper-figure benches to be re-runnable.

/// SplitMix64 step: used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG with Box–Muller gaussian support.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
    spare_gauss: Option<f64>,
}

impl Xoshiro256 {
    /// Seed via SplitMix64 (any seed, including 0, is fine).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s, spare_gauss: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform float in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform float in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal deviate (polar Box–Muller with spare caching).
    pub fn gauss(&mut self) -> f64 {
        if let Some(g) = self.spare_gauss.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare_gauss = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal deviate with mean/sigma.
    #[inline]
    pub fn gauss_ms(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.gauss()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork a statistically independent child stream (for worker threads).
    pub fn fork(&mut self) -> Self {
        Self::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Xoshiro256::new(7);
        let mut b = Xoshiro256::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::new(1);
        let mut b = Xoshiro256::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::new(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Xoshiro256::new(4);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Xoshiro256::new(5);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gauss();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn range_i64_inclusive() {
        let mut r = Xoshiro256::new(6);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2_000 {
            let v = r.range_i64(-1, 1);
            assert!((-1..=1).contains(&v));
            lo_seen |= v == -1;
            hi_seen |= v == 1;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(8);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_decorrelated() {
        let mut parent = Xoshiro256::new(9);
        let mut a = parent.fork();
        let mut b = parent.fork();
        let equal = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(equal, 0);
    }
}
