//! SRAM geometry: computational sub-arrays → mats → banks → cache slice.
//!
//! Paper §4.1 / Fig. 5: a 2.5 MB cache slice holds 80 × 32 KB banks
//! (organized in 20 ways); each bank has two 16 KB mats; each mat has two
//! 8 KB computational sub-arrays of 256 rows × 256 columns of read-write-
//! decoupled 8T cells.  Fig. 6(a): each compute sub-array is split into the
//! P (pixel, 64 rows), C (pivot, 64), Resv (64), W (weight, 32) and
//! I (input, 32) regions.
//!
//! [`SubArray`] is the bit-accurate storage + bulk-bitwise compute model:
//! rows are stored packed, 64 columns per `u64` word, and the three-row-
//! activation operations of the SA (§4.1) are word-parallel — this packing
//! *is* the performance model of the 256-wide bit-line parallelism (and the
//! crate's hot path; see benches/hotpath.rs).

use crate::error::{Error, Result};

/// Row-region split of a computational sub-array (Fig. 6a).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RegionLayout {
    pub pixel_rows: usize,
    pub pivot_rows: usize,
    pub reserved_rows: usize,
    pub weight_rows: usize,
    pub input_rows: usize,
}

impl Default for RegionLayout {
    fn default() -> Self {
        // Paper: P=64, C=64, Resv=64, W=32, I=32 (total 256).
        Self {
            pixel_rows: 64,
            pivot_rows: 64,
            reserved_rows: 64,
            weight_rows: 32,
            input_rows: 32,
        }
    }
}

/// Named region of a sub-array.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Region {
    /// P: transposed pixel bit-planes.
    Pixel,
    /// C: replicated transposed pivot bit-planes.
    Pivot,
    /// Resv: Result_array, LBP_array, all-zero/all-one rows, scratch.
    Reserved,
    /// W: MLP weight bit-planes.
    Weight,
    /// I: MLP input-activation bit-planes.
    Input,
}

impl RegionLayout {
    pub fn total_rows(&self) -> usize {
        self.pixel_rows + self.pivot_rows + self.reserved_rows
            + self.weight_rows + self.input_rows
    }

    /// First row index of `region`.
    pub fn base(&self, region: Region) -> usize {
        match region {
            Region::Pixel => 0,
            Region::Pivot => self.pixel_rows,
            Region::Reserved => self.pixel_rows + self.pivot_rows,
            Region::Weight => self.pixel_rows + self.pivot_rows + self.reserved_rows,
            Region::Input => {
                self.pixel_rows + self.pivot_rows + self.reserved_rows
                    + self.weight_rows
            }
        }
    }

    /// Row count of `region`.
    pub fn len(&self, region: Region) -> usize {
        match region {
            Region::Pixel => self.pixel_rows,
            Region::Pivot => self.pivot_rows,
            Region::Reserved => self.reserved_rows,
            Region::Weight => self.weight_rows,
            Region::Input => self.input_rows,
        }
    }

    /// Global row index of `offset` within `region`, bounds-checked.
    pub fn row(&self, region: Region, offset: usize) -> Result<usize> {
        if offset >= self.len(region) {
            return Err(Error::Mapping(format!(
                "row {offset} out of range for {region:?} (len {})",
                self.len(region)
            )));
        }
        Ok(self.base(region) + offset)
    }

    /// Which region a global row index falls in.
    pub fn region_of(&self, row: usize) -> Option<Region> {
        let mut base = 0;
        for r in [Region::Pixel, Region::Pivot, Region::Reserved,
                  Region::Weight, Region::Input] {
            base += self.len(r);
            if row < base {
                return Some(r);
            }
        }
        None
    }
}

/// Whole-cache geometry (paper Fig. 5a).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CacheGeometry {
    pub banks: usize,
    pub mats_per_bank: usize,
    pub subarrays_per_mat: usize,
    pub rows: usize,
    pub cols: usize,
    pub region: RegionLayout,
}

impl Default for CacheGeometry {
    fn default() -> Self {
        // 80 banks × 2 mats × 2 sub-arrays × (256×256 bits = 8 KB) = 2.5 MB
        Self {
            banks: 80,
            mats_per_bank: 2,
            subarrays_per_mat: 2,
            rows: 256,
            cols: 256,
            region: RegionLayout::default(),
        }
    }
}

impl CacheGeometry {
    pub fn validate(&self) -> Result<()> {
        if self.rows == 0 || self.cols == 0 || self.banks == 0
            || self.mats_per_bank == 0 || self.subarrays_per_mat == 0
        {
            return Err(Error::Config("cache dimensions must be non-zero".into()));
        }
        if self.region.total_rows() != self.rows {
            return Err(Error::Config(format!(
                "region rows {} != sub-array rows {}",
                self.region.total_rows(),
                self.rows
            )));
        }
        if self.cols % 64 != 0 {
            return Err(Error::Config(format!(
                "cols must be a multiple of 64 (u64 packing), got {}",
                self.cols
            )));
        }
        Ok(())
    }

    pub fn total_subarrays(&self) -> usize {
        self.banks * self.mats_per_bank * self.subarrays_per_mat
    }

    /// Sub-array capacity in bytes (paper: 8 KB).
    pub fn subarray_bytes(&self) -> usize {
        self.rows * self.cols / 8
    }

    /// Total slice capacity in bytes (paper: 2.5 MB).
    pub fn total_bytes(&self) -> usize {
        self.total_subarrays() * self.subarray_bytes()
    }
}

/// One computational sub-array: packed bit matrix + bulk-bitwise ops.
///
/// Storage is `rows × (cols/64)` little-endian `u64` words; column `c` of
/// row `r` lives in word `c / 64`, bit `c % 64`.  All compute ops are
/// whole-row (all 256 bit-lines fire in one memory cycle — the paper's
/// single-cycle claim), operating word-parallel.
#[derive(Clone, Debug)]
pub struct SubArray {
    rows: usize,
    cols: usize,
    words_per_row: usize,
    data: Vec<u64>,
}

impl SubArray {
    pub fn new(rows: usize, cols: usize) -> Self {
        assert!(cols % 64 == 0, "cols must be a multiple of 64");
        let words_per_row = cols / 64;
        Self { rows, cols, words_per_row, data: vec![0; rows * words_per_row] }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    fn check_row(&self, row: usize) -> Result<()> {
        if row >= self.rows {
            return Err(Error::Isa(format!(
                "row address {row} out of range (rows={})",
                self.rows
            )));
        }
        Ok(())
    }

    #[inline]
    fn row_slice(&self, row: usize) -> &[u64] {
        &self.data[row * self.words_per_row..(row + 1) * self.words_per_row]
    }

    #[inline]
    fn row_slice_mut(&mut self, row: usize) -> &mut [u64] {
        &mut self.data[row * self.words_per_row..(row + 1) * self.words_per_row]
    }

    /// Read a single bit (standard decoupled-read-port access).
    pub fn get(&self, row: usize, col: usize) -> Result<bool> {
        self.check_row(row)?;
        if col >= self.cols {
            return Err(Error::Isa(format!("col {col} out of range")));
        }
        Ok(self.row_slice(row)[col / 64] >> (col % 64) & 1 == 1)
    }

    /// Write a single bit (WWL + WBL/WBLB access).
    pub fn set(&mut self, row: usize, col: usize, v: bool) -> Result<()> {
        self.check_row(row)?;
        if col >= self.cols {
            return Err(Error::Isa(format!("col {col} out of range")));
        }
        let w = &mut self.row_slice_mut(row)[col / 64];
        if v {
            *w |= 1 << (col % 64);
        } else {
            *w &= !(1 << (col % 64));
        }
        Ok(())
    }

    /// Read a whole row as packed words (one read cycle).
    pub fn read_row(&self, row: usize) -> Result<Vec<u64>> {
        self.check_row(row)?;
        Ok(self.row_slice(row).to_vec())
    }

    /// Read a whole row into a caller buffer without allocating.
    pub fn read_row_into(&self, row: usize, out: &mut [u64]) -> Result<()> {
        self.check_row(row)?;
        out.copy_from_slice(self.row_slice(row));
        Ok(())
    }

    /// Borrow a row's packed words directly (hot path; no copy).
    pub fn row_words(&self, row: usize) -> Result<&[u64]> {
        self.check_row(row)?;
        Ok(self.row_slice(row))
    }

    /// Write a whole row from packed words (one write cycle).
    pub fn write_row(&mut self, row: usize, words: &[u64]) -> Result<()> {
        self.check_row(row)?;
        if words.len() != self.words_per_row {
            return Err(Error::Isa(format!(
                "row write width {} != {}",
                words.len() * 64,
                self.cols
            )));
        }
        self.row_slice_mut(row).copy_from_slice(words);
        Ok(())
    }

    /// Fill a row with all-zero or all-one (the `NS-LBP ini` opcode).
    pub fn fill_row(&mut self, row: usize, ones: bool) -> Result<()> {
        self.check_row(row)?;
        let v = if ones { u64::MAX } else { 0 };
        self.row_slice_mut(row).fill(v);
        Ok(())
    }

    /// Two-row bulk op helper: applies `f` word-wise to rows `a`, `b`.
    pub fn rowwise2(&self, a: usize, b: usize,
                    mut f: impl FnMut(u64, u64) -> u64) -> Result<Vec<u64>> {
        self.check_row(a)?;
        self.check_row(b)?;
        let (ra, rb) = (self.row_slice(a), self.row_slice(b));
        Ok(ra.iter().zip(rb).map(|(&x, &y)| f(x, y)).collect())
    }

    /// Three-row bulk op helper (the three-RWL activation of §4.1).
    pub fn rowwise3(&self, a: usize, b: usize, c: usize,
                    mut f: impl FnMut(u64, u64, u64) -> u64) -> Result<Vec<u64>> {
        self.check_row(a)?;
        self.check_row(b)?;
        self.check_row(c)?;
        let (ra, rb, rc) = (self.row_slice(a), self.row_slice(b), self.row_slice(c));
        Ok(ra
            .iter()
            .zip(rb)
            .zip(rc)
            .map(|((&x, &y), &z)| f(x, y, z))
            .collect())
    }

    /// Allocation-free two-row op: `dest ← f(a, b)` in place (hot path —
    /// models the same single-cycle activation as [`Self::rowwise2`], the
    /// result latching directly through the decoupled write port).
    pub fn op2_into(&mut self, a: usize, b: usize, dest: usize,
                    f: impl Fn(u64, u64) -> u64) -> Result<()> {
        self.check_row(a)?;
        self.check_row(b)?;
        self.check_row(dest)?;
        let w = self.words_per_row;
        for i in 0..w {
            let x = self.data[a * w + i];
            let y = self.data[b * w + i];
            self.data[dest * w + i] = f(x, y);
        }
        Ok(())
    }

    /// Allocation-free three-row op: `dest ← f(a, b, c)` in place.
    pub fn op3_into(&mut self, a: usize, b: usize, c: usize, dest: usize,
                    f: impl Fn(u64, u64, u64) -> u64) -> Result<()> {
        self.check_row(a)?;
        self.check_row(b)?;
        self.check_row(c)?;
        self.check_row(dest)?;
        let w = self.words_per_row;
        for i in 0..w {
            let x = self.data[a * w + i];
            let y = self.data[b * w + i];
            let z = self.data[c * w + i];
            self.data[dest * w + i] = f(x, y, z);
        }
        Ok(())
    }

    /// Allocation-free row copy.
    pub fn copy_row(&mut self, src: usize, dest: usize) -> Result<()> {
        self.check_row(src)?;
        self.check_row(dest)?;
        let w = self.words_per_row;
        self.data.copy_within(src * w..(src + 1) * w, dest * w);
        Ok(())
    }
}

/// Address of one sub-array inside the cache slice.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SubArrayId {
    pub bank: usize,
    pub mat: usize,
    pub subarray: usize,
}

/// The full near-sensor cache slice: `banks × mats × subarrays` compute
/// sub-arrays plus the geometry they share.
#[derive(Clone, Debug)]
pub struct CacheSlice {
    pub geometry: CacheGeometry,
    arrays: Vec<SubArray>,
}

impl CacheSlice {
    pub fn new(geometry: CacheGeometry) -> Result<Self> {
        geometry.validate()?;
        let n = geometry.total_subarrays();
        let arrays = (0..n)
            .map(|_| SubArray::new(geometry.rows, geometry.cols))
            .collect();
        Ok(Self { geometry, arrays })
    }

    fn index(&self, id: SubArrayId) -> Result<usize> {
        let g = &self.geometry;
        if id.bank >= g.banks || id.mat >= g.mats_per_bank
            || id.subarray >= g.subarrays_per_mat
        {
            return Err(Error::Mapping(format!("sub-array id out of range: {id:?}")));
        }
        Ok((id.bank * g.mats_per_bank + id.mat) * g.subarrays_per_mat + id.subarray)
    }

    pub fn subarray(&self, id: SubArrayId) -> Result<&SubArray> {
        Ok(&self.arrays[self.index(id)?])
    }

    pub fn subarray_mut(&mut self, id: SubArrayId) -> Result<&mut SubArray> {
        let i = self.index(id)?;
        Ok(&mut self.arrays[i])
    }

    /// Iterate all sub-array ids in (bank, mat, subarray) order.
    pub fn ids(&self) -> impl Iterator<Item = SubArrayId> + '_ {
        let g = self.geometry;
        (0..g.banks).flat_map(move |bank| {
            (0..g.mats_per_bank).flat_map(move |mat| {
                (0..g.subarrays_per_mat)
                    .map(move |subarray| SubArrayId { bank, mat, subarray })
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_geometry_is_paper() {
        let g = CacheGeometry::default();
        g.validate().unwrap();
        assert_eq!(g.subarray_bytes(), 8 * 1024);               // 8 KB
        assert_eq!(g.total_subarrays(), 320);                   // 80×2×2
        assert_eq!(g.total_bytes(), 2 * 1024 * 1024 + 512 * 1024); // 2.5 MB
    }

    #[test]
    fn region_layout_covers_all_rows() {
        let r = RegionLayout::default();
        assert_eq!(r.total_rows(), 256);
        assert_eq!(r.base(Region::Pixel), 0);
        assert_eq!(r.base(Region::Pivot), 64);
        assert_eq!(r.base(Region::Reserved), 128);
        assert_eq!(r.base(Region::Weight), 192);
        assert_eq!(r.base(Region::Input), 224);
        for row in 0..256 {
            assert!(r.region_of(row).is_some());
        }
        assert_eq!(r.region_of(256), None);
    }

    #[test]
    fn region_row_bounds_checked() {
        let r = RegionLayout::default();
        assert_eq!(r.row(Region::Pivot, 0).unwrap(), 64);
        assert!(r.row(Region::Weight, 32).is_err());
    }

    #[test]
    fn subarray_bit_roundtrip() {
        let mut sa = SubArray::new(256, 256);
        sa.set(3, 200, true).unwrap();
        assert!(sa.get(3, 200).unwrap());
        sa.set(3, 200, false).unwrap();
        assert!(!sa.get(3, 200).unwrap());
        assert!(sa.get(256, 0).is_err());
        assert!(sa.get(0, 256).is_err());
    }

    #[test]
    fn fill_and_rowwise_ops() {
        let mut sa = SubArray::new(8, 128);
        sa.fill_row(0, true).unwrap();
        sa.fill_row(1, false).unwrap();
        let xor = sa.rowwise2(0, 1, |a, b| a ^ b).unwrap();
        assert!(xor.iter().all(|&w| w == u64::MAX));
        let maj = sa.rowwise3(0, 0, 1, |a, b, c| (a & b) | (a & c) | (b & c)).unwrap();
        assert!(maj.iter().all(|&w| w == u64::MAX));
    }

    #[test]
    fn geometry_rejects_bad_region_split() {
        let mut g = CacheGeometry::default();
        g.region.pixel_rows = 63;
        assert!(g.validate().is_err());
    }

    #[test]
    fn cache_slice_addressing() {
        let g = CacheGeometry { banks: 2, mats_per_bank: 2, subarrays_per_mat: 2,
                                ..CacheGeometry::default() };
        let mut slice = CacheSlice::new(g).unwrap();
        let id = SubArrayId { bank: 1, mat: 0, subarray: 1 };
        slice.subarray_mut(id).unwrap().set(0, 0, true).unwrap();
        assert!(slice.subarray(id).unwrap().get(0, 0).unwrap());
        // a different sub-array is untouched
        let other = SubArrayId { bank: 0, mat: 0, subarray: 0 };
        assert!(!slice.subarray(other).unwrap().get(0, 0).unwrap());
        assert!(slice
            .subarray(SubArrayId { bank: 2, mat: 0, subarray: 0 })
            .is_err());
        assert_eq!(slice.ids().count(), 8);
    }

    #[test]
    fn row_words_matches_read_row() {
        let mut sa = SubArray::new(4, 192);
        sa.set(2, 100, true).unwrap();
        assert_eq!(sa.row_words(2).unwrap(), sa.read_row(2).unwrap().as_slice());
    }
}
