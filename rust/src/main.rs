//! `ns-lbp` — the NS-LBP near-sensor accelerator CLI (leader entrypoint).
//!
//! Subcommands:
//!
//! * `run`       — stream synthetic frames through the full pipeline
//!                 (sensor → mapper → in-memory LBP → MLP), print per-run
//!                 stats; `--backend functional|architectural|pjrt` picks
//!                 the execution path, `--cross-check KIND` re-runs every
//!                 frame on a reference backend and counts divergences;
//!                 `--arch-mlp` also simulates the MLP in-memory;
//!                 `--golden` cross-checks against the PJRT artifact.
//! * `serve-bench` — replay synthetic frames through the sharded, batching,
//!                 QoS-aware serving layer at a configurable offered load
//!                 and print the per-class latency/throughput/energy
//!                 report; `--backend` / `--cross-check` select the
//!                 per-shard engine, `--route class=backend` routes QoS
//!                 classes to backends, `--mix A:B:C` shapes the traffic
//!                 across best_effort:standard:billed, `--compare` also
//!                 runs the 1-shard baseline and prints the speedup, and
//!                 `--json` emits one machine-readable report;
//!                 `--trace out.jsonl` records the full request lifecycle
//!                 as a JSONL span feed plus a Chrome/Perfetto
//!                 `out.trace.json`.
//! * `fleet-bench` — replay the same traffic through an N-node fleet
//!                 (rendezvous-hash routing, per-node serve planes) and
//!                 print the fleet rollup with a per-node breakdown;
//!                 `--drill` kills `[fleet.drill] kill_node` mid-stream
//!                 and reports re-homing + p99 inflation against the
//!                 undisturbed baseline pass, `--push-rollover` rolls a
//!                 synthetic compiled artifact through the live nodes as
//!                 model 1 (acks must converge on one content-hash
//!                 version), `--nodes/--kill-node/--kill-after` override
//!                 the `[fleet]` config, and `--json` emits one
//!                 machine-readable document (`BENCH_fleet.json` in CI);
//!                 with `--trace out.jsonl` each node writes its own
//!                 `out-node<i>.jsonl` feed (the drill pass overwrites
//!                 the baseline's, as with `serve-bench --compare`).
//! * `compile`   — lower a model-spec TOML (`configs/models/*.toml`)
//!                 through the staged analyze→map→pack→price pipeline to
//!                 a versioned `.nslbpc` artifact (stage outputs cached
//!                 on disk, so recompiles are incremental); `--check`
//!                 reloads the artifact and proves engines built from it
//!                 are bit-identical to from-params engines; serve it
//!                 with `serve-bench --model-artifact FILE`.
//! * `trace`     — summarize one or more JSONL trace feeds
//!                 (`ns-lbp trace out.jsonl`, or several: `ns-lbp trace
//!                 out-node0.jsonl out-node1.jsonl …` merges them into
//!                 one timeline): per-stage p50/p95/p99 latency, energy
//!                 by stage, drop causes; `--json` emits the summary
//!                 machine-readably and `--chrome OUT.trace.json` also
//!                 writes a merged Chrome/Perfetto trace with one
//!                 process per feed.
//! * `ab`        — the A/B energy harness: run the same frames through
//!                 two engines under two hardware profiles
//!                 (`--profile A --profile B`) and print/`--json`-emit a
//!                 side-by-side diff of energy, time, TOPS/W and area.
//! * `profile`   — print a hardware profile as a standalone TOML file
//!                 (the `configs/profiles/*.toml` format); with no name
//!                 given, list the built-in profile names.
//! * `chaos`     — run one named, seeded fault-injection scenario
//!                 (`--scenario flaky-transport|slow-shard|node-flap|
//!                 bitflip-sweep`) against the fleet/serve planes and
//!                 report the recovery evidence: injected-fault ledger,
//!                 recovery p99 vs `[faults] p99_budget`, billed loss,
//!                 and completed-frame logit divergence against a
//!                 fault-free pass; the seeded schedule section of the
//!                 `--json` document is byte-identical across runs with
//!                 the same `--seed` (`BENCH_chaos.json` in CI).
//! * `transient` — print the Fig. 9 RBL discharge waveforms.
//! * `montecarlo`— run the Fig. 10 variation analysis.
//! * `info`      — show configuration, geometry, energy/area headline.
//!
//! Configuration: `--config configs/nslbp_default.toml` plus repeated
//! `--set section.key=value` overrides (backend selection is also
//! reachable as `--set engine.backend=...`); `--hw-profile NAME|PATH`
//! swaps the hardware cost model everywhere.

use ns_lbp::circuit::{MonteCarlo, SENSE_DELAY_PS};
use ns_lbp::cli::Command;
use ns_lbp::compile::{CompileOptions, CompiledModel, ModelSpec};
use ns_lbp::config::SystemConfig;
use ns_lbp::coordinator::{ArchSim, Coordinator, CoordinatorConfig};
use ns_lbp::engine::{BackendKind, Engine, QosClass};
use ns_lbp::hw::{ab::AbHarness, CostModel, HwProfile};
use ns_lbp::params::NetParams;
use ns_lbp::sensor::Frame;
use ns_lbp::serve::{parse_mix, Server, Ticket};
use ns_lbp::testing::synth_frames;
use ns_lbp::{params, Result};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match real_main(&args) {
        Ok(()) => {}
        Err(ns_lbp::Error::Usage(msg)) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn command() -> Command {
    Command::new("ns-lbp", "near-sensor LBP accelerator simulator")
        .subcommand("run", "stream frames through the pipeline")
        .subcommand("serve-bench", "drive the sharded, batching serve layer")
        .subcommand("fleet-bench", "drive an N-node fleet; --drill kills a \
                                    node mid-stream, --push-rollover rolls \
                                    a model through the survivors")
        .subcommand("compile", "compile a model spec to a versioned artifact")
        .subcommand("ab", "A/B energy harness: two hw profiles, same frames")
        .subcommand("trace", "summarize JSONL trace feeds (several merge \
                              into one timeline)")
        .subcommand("profile", "print a hardware profile as TOML (no name: \
                                list built-ins)")
        .subcommand("chaos", "seeded fault-injection scenarios over the \
                              serve/fleet planes (flaky-transport, \
                              slow-shard, node-flap, bitflip-sweep)")
        .subcommand("transient", "Fig. 9 RBL discharge waveforms")
        .subcommand("montecarlo", "Fig. 10 sense-margin analysis")
        .subcommand("info", "configuration and headline numbers")
        .opt("config", "FILE", "config file (TOML subset)")
        .opt_repeated("set", "K=V", "config override, e.g. cache.banks=40")
        .opt("backend", "KIND", "inference backend: functional|architectural|pjrt")
        .opt("cross-check", "KIND", "reference backend to cross-check (or none)")
        .opt("hw-profile", "NAME|PATH",
             "hardware cost-model profile (ns_lbp_65nm|sram38_28nm|... or a \
              profile TOML path)")
        .opt_repeated("profile", "NAME|PATH",
                      "ab: one arm's hw profile (give exactly twice)")
        .opt("dataset", "NAME", "mnist|svhn (default mnist)")
        .opt("frames", "N", "frames to stream (default 8; serve-bench 256)")
        .opt("seed", "N", "frame-generator seed (default 7)")
        .opt("trials", "N", "Monte-Carlo trials (default 200)")
        .opt("artifacts", "DIR", "artifacts directory (default artifacts)")
        .opt("shards", "N", "serve-bench: shard workers (default serve.shards)")
        .opt("batch-size", "N", "serve-bench: max dispatch batch")
        .opt("deadline-us", "US", "serve-bench: batch deadline [µs]")
        .opt("queue-depth", "N", "serve-bench: admission-control depth")
        .opt("load", "FPS", "serve-bench: offered load (0 = unthrottled)")
        .opt("sensors", "N",
             "serve-bench: distinct sensor streams the frames fan out \
              across (default: one per class×model pair)")
        .opt_repeated("route", "CLASS=BACKEND",
                      "route a QoS class to a backend, e.g. billed=architectural")
        .opt("mix", "A:B:C",
             "serve-bench: best_effort:standard:billed traffic weights (default 0:1:0)")
        .opt("trace", "FILE",
             "serve-bench: write a JSONL trace feed (and FILE's .trace.json \
              Chrome/Perfetto twin); fleet-bench: per-node FILE-node<i>.jsonl \
              feeds")
        .opt("nodes", "N", "fleet-bench: fleet size (default fleet.nodes)")
        .opt("kill-node", "N",
             "fleet-bench --drill: node to kill (default fleet.drill.kill_node)")
        .opt("kill-after", "N",
             "fleet-bench --drill: kill after N submitted frames \
              (0 = halfway; default fleet.drill.kill_after)")
        .opt("scenario", "NAME",
             "chaos: flaky-transport|slow-shard|node-flap|bitflip-sweep")
        .opt("chrome", "FILE",
             "trace: also write a merged Chrome trace of all feeds \
              (one process per feed)")
        .opt_repeated("model-artifact", "FILE",
                      "serve-bench: also serve this compiled artifact \
                       (model ids 1, 2, ... in option order)")
        .opt("out-dir", "DIR",
             "compile: artifact output directory (default [compile] out_dir)")
        .opt("cache-dir", "DIR",
             "compile: stage-cache directory (default [compile] cache_dir)")
        .flag("check",
              "compile: reload the artifact and verify engines built from \
               it match from-params engines bit for bit")
        .flag("json", "serve-bench: emit one machine-readable JSON report")
        .flag("compare", "serve-bench: also run 1 shard, print speedup")
        .flag("async", "serve-bench: run the event-driven serve plane \
                        ([serve.async]: DRR fairness + shard autoscaling)")
        .flag("drill", "fleet-bench: kill fleet.drill.kill_node mid-stream \
                        and gate re-homing against the baseline pass")
        .flag("push-rollover", "fleet-bench: roll a synthetic compiled \
                                artifact through the live nodes as model 1")
        .flag("arch-mlp", "simulate the MLP in-memory too")
        .flag("early-exit", "enable Algorithm-1 early exit")
        .flag("golden", "cross-check logits against the PJRT artifact")
        .flag("functional", "skip the architectural simulation")
}

fn real_main(args: &[String]) -> Result<()> {
    let cmd = command();
    let parsed = cmd.parse(args)?;
    let overrides = parsed.opt_all("set");
    let mut system = SystemConfig::load(parsed.opt("config"), &overrides)?;
    apply_engine_opts(&parsed, &mut system)?;

    match parsed.subcommand.as_deref() {
        Some("run") => run_pipeline(&parsed, system),
        Some("serve-bench") => serve_bench(&parsed, system),
        Some("fleet-bench") => fleet_bench(&parsed, system),
        Some("chaos") => chaos_bench(&parsed, system),
        Some("compile") => compile_model(&parsed, system),
        Some("ab") => ab_compare(&parsed, system),
        Some("trace") => trace_summary(&parsed),
        Some("profile") => dump_profile(&parsed, &system),
        Some("transient") => transient(system),
        Some("montecarlo") => montecarlo(&parsed, system),
        Some("info") | None => info(system),
        Some(other) => Err(ns_lbp::Error::Usage(format!(
            "unknown subcommand {other:?}"
        ))),
    }
}

/// Fold `--backend` / `--cross-check` / `--route` into the engine
/// selection (they override both the config file and `--set engine.*`).
fn apply_engine_opts(parsed: &ns_lbp::cli::Parsed, system: &mut SystemConfig)
                     -> Result<()> {
    if let Some(b) = parsed.opt("backend") {
        system.engine.backend = b.parse()?;
    }
    if let Some(c) = parsed.opt("cross-check") {
        system.engine.cross_check = BackendKind::parse_optional(c)?;
    }
    if let Some(p) = parsed.opt("hw-profile") {
        system.hw.profile = HwProfile::resolve(p)?;
    }
    for spec in parsed.opt_all("route") {
        system.engine.routing.apply_spec(&spec)?;
    }
    Ok(())
}

/// Resolve `--dataset` / `--artifacts` and keep the engine's artifact
/// view in sync, so a PJRT backend resolves the same files the params
/// came from.  Returns `(dataset, artifacts_dir)`.
fn resolve_artifacts(parsed: &ns_lbp::cli::Parsed, system: &mut SystemConfig)
                     -> (String, String) {
    let dataset = parsed.opt("dataset").unwrap_or("mnist").to_string();
    let artifacts = parsed
        .opt("artifacts")
        .unwrap_or(&system.artifacts_dir)
        .to_string();
    system.artifacts_dir = artifacts.clone();
    if parsed.opt("dataset").is_some() {
        system.engine.pjrt_artifact = format!("aplbp_{dataset}");
    }
    (dataset, artifacts)
}

fn engine_banner(system: &SystemConfig) -> String {
    let mut banner = match system.engine.cross_check {
        Some(c) => format!("{} (cross-check: {})", system.engine.backend, c),
        None => system.engine.backend.to_string(),
    };
    let routes: Vec<String> = QosClass::ALL
        .iter()
        .filter_map(|&class| {
            system
                .engine
                .routing
                .route(class)
                .map(|kind| format!("{class}→{kind}"))
        })
        .collect();
    if !routes.is_empty() {
        banner.push_str(&format!(" [routes: {}]", routes.join(", ")));
    }
    banner
}

fn run_pipeline(parsed: &ns_lbp::cli::Parsed, mut system: SystemConfig)
                -> Result<()> {
    let frames_n: usize = parsed.opt_parse("frames", 8)?;
    let seed: u64 = parsed.opt_parse("seed", 7)?;
    let (dataset, artifacts) = resolve_artifacts(parsed, &mut system);

    let params = params::load(format!("{artifacts}/{dataset}.params.bin"))?;
    let cfg = params.config;
    println!(
        "network: {dataset} ({}x{}x{}, {} LBP layers, apx={}, hidden {}) | \
         backend: {}",
        cfg.height, cfg.width, cfg.in_channels, cfg.n_lbp_layers,
        cfg.apx_code, cfg.hidden, engine_banner(&system)
    );

    let frames = synth_frames(&params, frames_n, seed)?;
    let arch = ArchSim {
        lbp: !parsed.flag("functional"),
        mlp: parsed.flag("arch-mlp"),
        early_exit: parsed.flag("early-exit"),
    };
    let coord = Coordinator::new(
        params.clone(),
        CoordinatorConfig { system, arch, shard: None },
    )?;
    let (reports, summary) = coord.run_frames(&frames)?;

    for r in &reports {
        println!(
            "frame {:>3}: class {} ({} instrs, {:.2} µJ, {:.2} µs modeled)",
            r.seq,
            r.predicted,
            r.telemetry.exec.instructions,
            r.telemetry.cost.energy.total_pj() / 1e6,
            r.telemetry.cost.time_ns / 1e3
        );
    }
    println!(
        "summary: {} frames | mismatches {} | {:.2} µJ/frame | \
         {:.0} fps modeled | wall {:.2}s",
        summary.frames,
        summary.arch_mismatches,
        summary.energy_per_frame_uj(),
        summary.frames_per_second_modeled(),
        summary.wall_seconds
    );
    if coord.config.system.engine.cross_check.is_some() {
        println!(
            "cross-check: {} logit mismatches over {} frames",
            summary.cross_check_mismatches, summary.frames
        );
    }
    if summary.arch_mismatches != 0 {
        return Err(ns_lbp::Error::Coordinator(
            "architectural/functional divergence detected".into(),
        ));
    }
    if summary.cross_check_mismatches != 0 {
        return Err(ns_lbp::Error::Engine(
            "cross-check divergence detected".into(),
        ));
    }

    if parsed.flag("golden") {
        let mut engine = Engine::builder()
            .config(coord.config.clone())
            .params(params)
            .backend(BackendKind::Pjrt)
            .no_cross_check()
            .artifact(format!("aplbp_{dataset}"))
            .build()?;
        println!("golden check on {} ...", engine.capabilities().detail);
        let b = 4.min(frames.len());
        let out = engine.infer_batch(&frames[..b])?;
        for (o, r) in out.frames.iter().zip(&reports) {
            println!(
                "  frame {}: pjrt class {}, simulator class {}",
                o.seq, o.predicted, r.predicted
            );
            if o.predicted != r.predicted {
                return Err(ns_lbp::Error::Runtime(
                    "golden model disagreement".into(),
                ));
            }
        }
        println!("golden check OK");
    }
    Ok(())
}

/// Outcome of one [`serve_replay`] pass: the drained report plus the
/// async-plane counters (when that plane ran) and the per-sensor
/// completed-count spread the soak fairness gate checks.
struct ServeRun {
    report: ns_lbp::serve::MetricsReport,
    async_stats: Option<ns_lbp::serve::AsyncStats>,
    fairness_spread: u64,
    admission_retries: u64,
}

/// Replay `frames` through one server instance at `load` offered fps
/// (0 = unthrottled), cycling frames through the `mix` class pattern,
/// round-robin across the served models (the from-params default plus
/// one pushed model per `--model-artifact`), and round-robin across
/// `sensors` distinct sensor streams.  Rejected submissions are retried
/// so every frame is offered; tickets shed by drop-oldest admission or
/// deadline expiry count as drops, not errors.
#[allow(clippy::too_many_arguments)]
fn serve_replay(params: &NetParams, system: &SystemConfig, arch: ArchSim,
                shards: usize, frames: &[Frame], load: f64,
                mix: &[QosClass], models: &[CompiledModel], sensors: usize)
                -> Result<ServeRun> {
    let mut system = system.clone();
    system.serve.shards = shards;
    let server = Server::start(
        params.clone(),
        CoordinatorConfig { system, arch, shard: None },
    )?;
    for (i, model) in models.iter().enumerate() {
        // the replayed frames were synthesized against the default
        // geometry, so every served model must share it — otherwise
        // admission would reject the frames and the retry loop would
        // spin forever
        let (m, d) = (&model.params.config, &params.config);
        if (m.height, m.width, m.in_channels)
            != (d.height, d.width, d.in_channels)
        {
            return Err(ns_lbp::Error::Usage(format!(
                "--model-artifact {}: geometry {}x{}x{} does not match the \
                 replayed frames ({}x{}x{})",
                model.name, m.height, m.width, m.in_channels,
                d.height, d.width, d.in_channels
            )));
        }
        server.push_model(i as u32 + 1, model)?;
    }
    let n_models = models.len() + 1;
    let sensors = sensors.max(1);
    // the caller-side seq ledger advances only on accepted admissions,
    // so retried rejections never punch holes in a sensor's seq space
    let mut seqs: std::collections::HashMap<u32, u64> =
        std::collections::HashMap::new();
    let t0 = std::time::Instant::now();
    let mut tickets: Vec<Ticket> = Vec::with_capacity(frames.len());
    // admission-control rejections retry under jittered exponential
    // backoff; the budget turns a wedged server into an error instead
    // of a silent spin
    let mut retrier = ns_lbp::faults::Retrier::new(
        ns_lbp::faults::RetryPolicy::admission(), 0x5e7e_ad31_0b5e_55ed);
    for (i, frame) in frames.iter().enumerate() {
        if load > 0.0 {
            let due = t0 + std::time::Duration::from_secs_f64(i as f64 / load);
            let now = std::time::Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
        }
        let class = mix[i % mix.len()];
        let model = (i % n_models) as u32;
        let sensor = (i % sensors) as u32;
        let seq = *seqs.get(&sensor).unwrap_or(&0);
        let ticket = retrier.run(|| {
            let request = ns_lbp::serve::Request::builder(
                frame.clone().with_seq(seq))
                .sensor_id(sensor)
                .class(class)
                .model(model)
                .build();
            server.submit(request)
        })?;
        seqs.insert(sensor, seq + 1);
        tickets.push(ticket);
    }
    let admission_retries = retrier.retries;
    let mut mismatches = 0u64;
    let mut cross_mismatches = 0u64;
    // every offered sensor starts at zero so a fully-shed stream still
    // counts against the fairness spread
    let mut completed: std::collections::HashMap<u32, u64> =
        seqs.keys().map(|&s| (s, 0)).collect();
    for t in tickets {
        match t.wait() {
            Ok(r) => {
                *completed.entry(r.sensor_id).or_insert(0) += 1;
                mismatches += r.report.telemetry.arch_mismatches;
                cross_mismatches += r.report.telemetry.cross_check_mismatches;
            }
            // shed by drop-oldest admission or a lapsed deadline: the
            // per-class drop counters in the report account for these
            Err(ns_lbp::Error::Dropped(_)) => {}
            Err(e) => return Err(e),
        }
    }
    // round-robin offered every sensor within one frame of every other,
    // so completed counts may spread only by that skew plus drops; DRR
    // keeps the drop side bounded per sensor instead of bursty
    let fairness_spread = match (completed.values().min(),
                                 completed.values().max()) {
        (Some(&lo), Some(&hi)) => hi - lo,
        _ => 0,
    };
    let async_stats = server.async_stats();
    let report = server.drain()?;
    if mismatches != 0 {
        return Err(ns_lbp::Error::Coordinator(format!(
            "{mismatches} architectural/functional divergences under serve"
        )));
    }
    if cross_mismatches != 0 {
        return Err(ns_lbp::Error::Engine(format!(
            "{cross_mismatches} cross-check divergences under serve"
        )));
    }
    Ok(ServeRun { report, async_stats, fairness_spread, admission_retries })
}

/// Render the async-plane counters as a JSON object (or `null` for the
/// thread-per-stage plane).
fn async_json(stats: &Option<ns_lbp::serve::AsyncStats>) -> String {
    match stats {
        None => "null".into(),
        Some(a) => format!(
            "{{\"workers\":{},\"min_shards\":{},\"max_shards\":{},\
             \"active_shards\":{},\"shards_high_water\":{},\
             \"scale_up_events\":{},\"scale_down_events\":{}}}",
            a.workers, a.min_shards, a.max_shards, a.active_shards,
            a.shards_high_water, a.scale_up_events, a.scale_down_events
        ),
    }
}

fn serve_bench(parsed: &ns_lbp::cli::Parsed, system: SystemConfig) -> Result<()> {
    let frames_n: usize = parsed.opt_parse("frames", 256)?;
    let seed: u64 = parsed.opt_parse("seed", 7)?;
    let load: f64 = parsed.opt_parse("load", 0.0)?;
    let sensors_opt: usize = parsed.opt_parse("sensors", 0)?;
    let json = parsed.flag("json");
    let mix = parse_mix(parsed.opt("mix").unwrap_or("0:1:0"))?;

    let mut system = system;
    if parsed.flag("async") {
        system.serve.async_plane.enabled = true;
    }
    if let Some(path) = parsed.opt("trace") {
        // --trace switches the obs pipeline on and points the feed at
        // FILE (its Chrome twin lands next to it); with --compare the
        // baseline run's feed is overwritten by the final run's
        system.obs.enabled = true;
        system.obs.jsonl_path = path.to_string();
    }
    system.serve.shards = parsed.opt_parse("shards", system.serve.shards)?;
    system.serve.max_batch =
        parsed.opt_parse("batch-size", system.serve.max_batch)?;
    system.serve.batch_deadline_us =
        parsed.opt_parse("deadline-us", system.serve.batch_deadline_us)?;
    system.serve.queue_depth =
        parsed.opt_parse("queue-depth", system.serve.queue_depth)?;
    system.serve.validate()?;

    let (dataset, artifacts) = resolve_artifacts(parsed, &mut system);
    let params = match params::load(format!("{artifacts}/{dataset}.params.bin")) {
        Ok(p) => {
            if !json {
                println!("network: {dataset} artifact");
            }
            p
        }
        Err(_) => {
            if !json {
                println!(
                    "network: synthetic (artifact \
                     {artifacts}/{dataset}.params.bin absent — run \
                     `make artifacts` for the real one)"
                );
            }
            params::synth::synth_params(seed).1
        }
    };

    let arch = ArchSim {
        lbp: !parsed.flag("functional"),
        mlp: parsed.flag("arch-mlp"),
        early_exit: parsed.flag("early-exit"),
    };
    let models: Vec<CompiledModel> = parsed
        .opt_all("model-artifact")
        .iter()
        .map(CompiledModel::load)
        .collect::<Result<_>>()?;
    let frames = synth_frames(&params, frames_n, seed)?;
    // default stream fan-out keeps the historical one-stream-per
    // (class, model) pair shape when --sensors isn't given
    let sensors = if sensors_opt == 0 {
        (models.len() + 1) * QosClass::COUNT
    } else {
        sensors_opt
    };
    let mix_banner: Vec<String> =
        mix.iter().map(|c| c.as_str().to_string()).collect();
    if !json {
        println!(
            "offered: {} frames at {} over {} sensors | backend {} | \
             mix [{}] | shards {} | batch ≤{} | deadline {} µs | \
             queue depth {}{}",
            frames.len(),
            if load > 0.0 { format!("{load:.0} fps") }
            else { "full rate".into() },
            sensors,
            engine_banner(&system),
            mix_banner.join(","),
            system.serve.shards,
            system.serve.max_batch,
            system.serve.batch_deadline_us,
            system.serve.queue_depth,
            if system.serve.async_plane.enabled { " | async plane" }
            else { "" },
        );
        for (i, m) in models.iter().enumerate() {
            println!(
                "model {:>4}: {} v{:016x} (from artifact)",
                i + 1, m.name, m.version
            );
        }
    }

    let shard_counts: Vec<usize> = if parsed.flag("compare") {
        vec![1, system.serve.shards]
    } else {
        vec![system.serve.shards]
    };
    let mut results = Vec::new();
    for &n in &shard_counts {
        let run = serve_replay(&params, &system, arch, n, &frames, load,
                               &mix, &models, sensors)?;
        if !json {
            run.report.print(&format!("{n} shard(s)"));
            println!(
                "  modeled   : {:.0} fps on the accelerator's {}-way bank \
                 split",
                run.report.modeled_fps(n), n
            );
            println!(
                "  fairness  : per-sensor completed-frame spread {}",
                run.fairness_spread
            );
            if let Some(a) = &run.async_stats {
                println!(
                    "  async     : {} workers | shards {}..{} (high water \
                     {}, now {}) | scale +{} / -{}",
                    a.workers, a.min_shards, a.max_shards,
                    a.shards_high_water, a.active_shards,
                    a.scale_up_events, a.scale_down_events
                );
            }
        }
        results.push((n, run));
    }
    if json {
        // exactly one JSON document on stdout, so
        // `ns-lbp serve-bench --json > BENCH_serve.json` is parseable;
        // the resolved per-class routes are recorded so the trajectory
        // file shows which backend produced each class's numbers
        let routes: Vec<String> = QosClass::ALL
            .iter()
            .map(|&class| {
                format!(
                    "\"{}\":\"{}\"",
                    class,
                    system.engine.routing.resolve(class,
                                                  system.engine.backend)
                )
            })
            .collect();
        let mut s = format!(
            "{{\"frames\":{},\"sensors\":{},\"backend\":\"{}\",\
             \"routes\":{{{}}},\"load_fps\":{},\"results\":[",
            frames.len(),
            sensors,
            system.engine.backend,
            routes.join(","),
            load
        );
        for (i, (n, run)) in results.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"shards\":{},\"modeled_fps\":{},\"fairness_spread\":{},\
                 \"admission_retries\":{},\"async\":{},\"report\":{}}}",
                n,
                run.report.modeled_fps(*n),
                run.fairness_spread,
                run.admission_retries,
                async_json(&run.async_stats),
                run.report.to_json()
            ));
        }
        s.push_str("]}");
        println!("{s}");
    } else if let [(n1, r1), (n2, r2)] = results.as_slice() {
        println!(
            "speedup: {n2} shards vs {n1} → {:.2}x wall throughput \
             ({:.1} vs {:.1} fps)",
            r2.report.throughput_fps / r1.report.throughput_fps.max(1e-12),
            r2.report.throughput_fps,
            r1.report.throughput_fps
        );
    }
    Ok(())
}

/// One pass of fleet traffic: start an N-node fleet, replay `frames`
/// across `sensors` at `load`, optionally killing a node and/or rolling
/// a model mid-stream, and return the fleet rollup plus the per-class
/// offered counts the gates compare completions against.
struct FleetRun {
    report: ns_lbp::fleet::FleetReport,
    offered: [u64; QosClass::COUNT],
    push_acks: Option<Vec<(ns_lbp::fleet::NodeId, u64)>>,
    admission_retries: u64,
    /// Sum of per-response re-home counts the *clients* saw; the drill
    /// gate checks it against the router's own `rerouted` counter.
    rehomed_observed: u64,
}

#[allow(clippy::too_many_arguments)]
fn fleet_replay(params: &NetParams, system: &SystemConfig, arch: ArchSim,
                frames: &[Frame], load: f64, mix: &[QosClass],
                sensors: &[u32], kill: Option<(ns_lbp::fleet::NodeId, usize)>,
                rollover: Option<&CompiledModel>) -> Result<FleetRun> {
    let fleet = ns_lbp::fleet::Fleet::start(
        params.clone(),
        CoordinatorConfig { system: system.clone(), arch, shard: None },
    )?;
    // The rollover (if any) happens at the same point as the kill so
    // the drill exercises push-during-re-homing; without a kill it
    // lands halfway.
    let event_at = kill.map_or(frames.len() / 2, |(_, at)| at);
    let mut push_acks = None;
    let t0 = std::time::Instant::now();
    // The caller-side seq ledger only advances on accepted admissions,
    // so retried rejections never punch holes in a sensor's seq space
    // (the single-node comparison keys logits by (sensor, seq)).
    let mut seqs: std::collections::HashMap<u32, u64> =
        std::collections::HashMap::new();
    let mut tickets = Vec::with_capacity(frames.len());
    let mut offered = [0u64; QosClass::COUNT];
    // "every live node at class capacity" retries under jittered
    // exponential backoff instead of a flat 200 µs spin
    let mut retrier = ns_lbp::faults::Retrier::new(
        ns_lbp::faults::RetryPolicy::admission(), 0xf1ee_70ad_155e_ed00);
    for (i, frame) in frames.iter().enumerate() {
        if i == event_at {
            if let Some((node, _)) = kill {
                fleet.kill_node(node)?;
            }
            if let Some(model) = rollover {
                push_acks = Some(fleet.push_model(1, model)?);
            }
        }
        if load > 0.0 {
            let due = t0 + std::time::Duration::from_secs_f64(i as f64 / load);
            let now = std::time::Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
        }
        let sensor = sensors[i % sensors.len()];
        let class = mix[i % mix.len()];
        offered[class.index()] += 1;
        let seq = *seqs.get(&sensor).unwrap_or(&0);
        let ticket = retrier.run(|| {
            fleet.submit_stamped(sensor, class, 0, frame.clone().with_seq(seq))
        })?;
        seqs.insert(sensor, seq + 1);
        tickets.push(ticket);
    }
    let admission_retries = retrier.retries;
    let mut mismatches = 0u64;
    let mut cross_mismatches = 0u64;
    let mut rehomed_observed = 0u64;
    for t in tickets {
        match t.wait() {
            Ok(r) => {
                rehomed_observed += r.rerouted as u64;
                mismatches += r.inner.report.telemetry.arch_mismatches;
                cross_mismatches +=
                    r.inner.report.telemetry.cross_check_mismatches;
            }
            // shed downstream (drop-oldest / lapsed deadline) or lost to
            // a dying fleet: the rollup's drop/lost counters account for
            // these, and the billed-loss gate lives on the report
            Err(ns_lbp::Error::Dropped(_)) | Err(ns_lbp::Error::Serve(_)) => {}
            Err(e) => return Err(e),
        }
    }
    let report = fleet.drain()?;
    if mismatches != 0 {
        return Err(ns_lbp::Error::Coordinator(format!(
            "{mismatches} architectural/functional divergences under fleet"
        )));
    }
    if cross_mismatches != 0 {
        return Err(ns_lbp::Error::Engine(format!(
            "{cross_mismatches} cross-check divergences under fleet"
        )));
    }
    Ok(FleetRun { report, offered, push_acks, admission_retries, rehomed_observed })
}

fn offered_json(offered: &[u64; QosClass::COUNT]) -> String {
    let mut s = String::from("{");
    for class in QosClass::ALL {
        s.push_str(&format!("\"{}\":{},", class, offered[class.index()]));
    }
    s.pop();
    s.push('}');
    s
}

fn fleet_bench(parsed: &ns_lbp::cli::Parsed, system: SystemConfig) -> Result<()> {
    let frames_n: usize = parsed.opt_parse("frames", 256)?;
    let seed: u64 = parsed.opt_parse("seed", 7)?;
    let load: f64 = parsed.opt_parse("load", 0.0)?;
    let json = parsed.flag("json");
    let mix_spec = parsed.opt("mix").unwrap_or("0:1:0");
    let mix = parse_mix(mix_spec)?;

    let mut system = system;
    if let Some(path) = parsed.opt("trace") {
        // the fleet rewrites the path per node: FILE-node<i>.jsonl
        system.obs.enabled = true;
        system.obs.jsonl_path = path.to_string();
    }
    system.serve.shards = parsed.opt_parse("shards", system.serve.shards)?;
    system.serve.max_batch =
        parsed.opt_parse("batch-size", system.serve.max_batch)?;
    system.serve.batch_deadline_us =
        parsed.opt_parse("deadline-us", system.serve.batch_deadline_us)?;
    system.serve.queue_depth =
        parsed.opt_parse("queue-depth", system.serve.queue_depth)?;
    system.serve.validate()?;
    system.fleet.nodes = parsed.opt_parse("nodes", system.fleet.nodes)?;
    system.fleet.drill.kill_node =
        parsed.opt_parse("kill-node", system.fleet.drill.kill_node)?;
    system.fleet.drill.kill_after =
        parsed.opt_parse("kill-after", system.fleet.drill.kill_after)?;
    system.fleet.validate()?;

    let (dataset, artifacts) = resolve_artifacts(parsed, &mut system);
    let params = match params::load(format!("{artifacts}/{dataset}.params.bin")) {
        Ok(p) => {
            if !json {
                println!("network: {dataset} artifact");
            }
            p
        }
        Err(_) => {
            if !json {
                println!(
                    "network: synthetic (artifact \
                     {artifacts}/{dataset}.params.bin absent — run \
                     `make artifacts` for the real one)"
                );
            }
            params::synth::synth_params(seed).1
        }
    };
    let arch = ArchSim {
        lbp: !parsed.flag("functional"),
        mlp: parsed.flag("arch-mlp"),
        early_exit: parsed.flag("early-exit"),
    };
    let frames = synth_frames(&params, frames_n, seed)?;
    // Two sensor streams per node: enough spread that a killed node
    // owns sensors to re-home, few enough that streams stay deep.
    let sensors: Vec<u32> = (0..(system.fleet.nodes as u32 * 2)).collect();

    let drill = parsed.flag("drill");
    let rollover = if parsed.flag("push-rollover") {
        let spec = ModelSpec::parse(
            "[model]\nname = \"rollover\"\nseed = 23\n",
            std::path::Path::new("."),
        )?;
        Some(ns_lbp::compile::build_model(&spec, &system)?)
    } else {
        None
    };
    let kill_node = system.fleet.drill.kill_node;
    let kill_after = if system.fleet.drill.kill_after == 0 {
        frames.len() / 2
    } else {
        // clamp inside the stream so the kill actually fires
        system.fleet.drill.kill_after.min(frames.len().saturating_sub(1))
    };

    if !json {
        let mix_banner: Vec<String> =
            mix.iter().map(|c| c.as_str().to_string()).collect();
        println!(
            "fleet: {} nodes | {} frames at {} | backend {} | mix [{}] | \
             {} sensors | capacity {:?}/node",
            system.fleet.nodes,
            frames.len(),
            if load > 0.0 { format!("{load:.0} fps") }
            else { "full rate".into() },
            engine_banner(&system),
            mix_banner.join(","),
            sensors.len(),
            system.fleet.capacity,
        );
    }

    let baseline = fleet_replay(&params, &system, arch, &frames, load, &mix,
                                &sensors, None, None)?;
    if !json {
        baseline.report.print("baseline");
    }
    let drill_run = if drill || rollover.is_some() {
        let run = fleet_replay(&params, &system, arch, &frames, load, &mix,
                               &sensors,
                               drill.then_some((kill_node, kill_after)),
                               rollover.as_ref())?;
        if !json {
            run.report.print(if drill { "drill" } else { "rollover" });
            if drill {
                let inflation =
                    run.report.p99_ms / baseline.report.p99_ms.max(1e-9);
                println!(
                    "  drill gate: billed lost {} | rerouted {} (clients \
                     saw {}) | p99 {:.3} ms vs baseline {:.3} ms ({:.2}x, \
                     budget {:.1}x)",
                    run.report.billed_lost(), run.report.rerouted,
                    run.rehomed_observed,
                    run.report.p99_ms, baseline.report.p99_ms, inflation,
                    system.fleet.drill.p99_budget
                );
            }
            if let Some(acks) = &run.push_acks {
                println!(
                    "  rollover: model 1 acked by {} node(s), all at \
                     v{:016x}",
                    acks.len(),
                    acks.first().map(|&(_, v)| v).unwrap_or(0)
                );
            }
        }
        Some(run)
    } else {
        None
    };

    if json {
        // exactly one JSON document on stdout, so
        // `ns-lbp fleet-bench --json > BENCH_fleet.json` is parseable
        // (validated by scripts/fleet_check.py)
        let mut s = format!(
            "{{\"nodes\":{},\"frames\":{},\"mix\":\"{}\",\"load_fps\":{},\
             \"backend\":\"{}\",",
            system.fleet.nodes, frames.len(), mix_spec, load,
            system.engine.backend
        );
        s.push_str(&format!(
            "\"baseline\":{{\"offered_by_class\":{},\"report\":{}}},",
            offered_json(&baseline.offered),
            baseline.report.to_json()
        ));
        match &drill_run {
            Some(run) => {
                s.push_str("\"drill\":{");
                if drill {
                    s.push_str(&format!(
                        "\"killed_node\":{kill_node},\
                         \"kill_after\":{kill_after},"
                    ));
                }
                s.push_str(&format!(
                    "\"p99_budget\":{},\"baseline_p99_ms\":{},\
                     \"drill_p99_ms\":{},\"p99_inflation\":{},\
                     \"rehomed_observed\":{},\"admission_retries\":{},",
                    system.fleet.drill.p99_budget,
                    baseline.report.p99_ms,
                    run.report.p99_ms,
                    run.report.p99_ms / baseline.report.p99_ms.max(1e-9),
                    run.rehomed_observed,
                    run.admission_retries
                ));
                match &run.push_acks {
                    Some(acks) => {
                        s.push_str("\"push\":{\"model_id\":1,\"acks\":[");
                        for (i, &(node, version)) in acks.iter().enumerate() {
                            if i > 0 {
                                s.push(',');
                            }
                            s.push_str(&format!(
                                "{{\"node\":{node},\
                                 \"version\":\"{version:016x}\"}}"
                            ));
                        }
                        s.push_str("]},");
                    }
                    None => s.push_str("\"push\":null,"),
                }
                s.push_str(&format!(
                    "\"offered_by_class\":{},\"report\":{}}}",
                    offered_json(&run.offered),
                    run.report.to_json()
                ));
            }
            None => s.push_str("\"drill\":null"),
        }
        s.push('}');
        println!("{s}");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// chaos: seeded fault-injection scenarios
// ---------------------------------------------------------------------------

/// One fleet pass for the chaos harness: the drained rollup, the
/// completed-frame logits keyed by `(sensor, seq)` for the bit-identity
/// comparison, and the caller-side admission retry count.
struct ChaosPass {
    report: ns_lbp::fleet::FleetReport,
    logits: std::collections::HashMap<(u32, u64), Vec<f32>>,
    admission_retries: u64,
}

/// Replay `frames` through a fleet built from `system`, optionally over
/// a [`ns_lbp::faults::FaultyTransport`] armed with `plan`.  The plan
/// (when given) is disarmed before drain so shutdown control traffic
/// cannot be eaten by the schedule.
fn chaos_fleet_pass(
    params: &NetParams,
    system: &SystemConfig,
    frames: &[Frame],
    mix: &[QosClass],
    sensors: &[u32],
    plan: Option<&std::sync::Arc<ns_lbp::faults::FaultPlan>>,
    settle: std::time::Duration,
) -> Result<ChaosPass> {
    let arch = ArchSim { lbp: false, mlp: false, early_exit: false };
    let config =
        CoordinatorConfig { system: system.clone(), arch, shard: None };
    let fleet = match plan {
        Some(plan) => {
            // duplicates and held-back deliveries inflate queue
            // occupancy past the capacity-derived depth `start()` picks,
            // so size the channels generously
            let depth: usize =
                system.fleet.capacity.iter().sum::<usize>() * 4 + 64;
            let transport = ns_lbp::faults::FaultyTransport::new(
                Box::new(ns_lbp::fleet::ChannelTransport::new(depth)),
                std::sync::Arc::clone(plan),
            );
            ns_lbp::fleet::Fleet::start_with_transport(
                params.clone(), config, Box::new(transport))?
        }
        None => ns_lbp::fleet::Fleet::start(params.clone(), config)?,
    };
    let mut seqs: std::collections::HashMap<u32, u64> =
        std::collections::HashMap::new();
    let mut retrier = ns_lbp::faults::Retrier::new(
        ns_lbp::faults::RetryPolicy::admission(),
        system.faults.seed ^ 0xc4a0_5bad_c0de_0001,
    );
    let mut tickets = Vec::with_capacity(frames.len());
    for (i, frame) in frames.iter().enumerate() {
        let sensor = sensors[i % sensors.len()];
        let class = mix[i % mix.len()];
        let seq = *seqs.get(&sensor).unwrap_or(&0);
        let ticket = retrier.run(|| {
            fleet.submit_stamped(sensor, class, 0, frame.clone().with_seq(seq))
        })?;
        seqs.insert(sensor, seq + 1);
        tickets.push(ticket);
    }
    let mut logits = std::collections::HashMap::new();
    for t in tickets {
        // bounded wait, so a recovery bug fails the harness instead of
        // hanging it
        match t.wait_timeout(std::time::Duration::from_secs(30)) {
            Some(Ok(r)) => {
                logits.insert(
                    (r.inner.sensor_id, r.seq()),
                    r.inner.report.logits.clone(),
                );
            }
            // shed or lost under faults: the rollup's drop/lost
            // counters (and the billed-loss gate) account for these
            Some(Err(ns_lbp::Error::Dropped(_)))
            | Some(Err(ns_lbp::Error::Serve(_))) => {}
            Some(Err(e)) => return Err(e),
            None => {
                return Err(ns_lbp::Error::Serve(
                    "chaos: frame unresolved after 30 s".into(),
                ));
            }
        }
    }
    // a flap window is measured in message indexes, so once the frames
    // resolve only the probe stream advances it: the settle gives the
    // probes wall-clock time to walk the blackhole off the link and let
    // the dead node rejoin before the rollup is read
    if !settle.is_zero() {
        std::thread::sleep(settle);
    }
    if let Some(plan) = plan {
        plan.disarm();
    }
    let report = fleet.drain()?;
    Ok(ChaosPass { report, logits, admission_retries: retrier.retries })
}

/// The effective injection/recovery knobs, machine-readably.
fn faults_json(f: &ns_lbp::config::FaultsConfig) -> String {
    format!(
        "{{\"seed\":{},\"drop_prob\":{},\"dup_prob\":{},\"delay_prob\":{},\
         \"delay_slots\":{},\"flap_node\":{},\"flap_after\":{},\
         \"flap_len\":{},\"stall_prob\":{},\"stall_us\":{},\
         \"panic_prob\":{},\"artifact_corrupt_prob\":{},\
         \"bitflip_sigma_scale\":{},\"retransmit_ms\":{},\"probe_ms\":{},\
         \"suspect_ms\":{},\"dead_ms\":{},\"degrade_after\":{},\
         \"p99_budget\":{}}}",
        f.seed, f.drop_prob, f.dup_prob, f.delay_prob, f.delay_slots,
        f.flap_node, f.flap_after, f.flap_len, f.stall_prob, f.stall_us,
        f.panic_prob, f.artifact_corrupt_prob, f.bitflip_sigma_scale,
        f.retransmit_ms, f.probe_ms, f.suspect_ms, f.dead_ms,
        f.degrade_after, f.p99_budget
    )
}

/// The determinism proof: a digest over the pure wire schedule plus its
/// first non-`Deliver` slots.  Two runs with the same seed and knobs
/// print this section byte-identically (`scripts/chaos_check.py`
/// compares them verbatim).
fn schedule_json(plan: &ns_lbp::faults::FaultPlan, nodes: usize) -> String {
    let digest = plan.schedule_digest(nodes, 256);
    let events = plan.schedule_events(nodes, 96, 48);
    let mut s = format!("{{\"digest\":\"{digest:016x}\",\"events\":[");
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let arg = match e.fault {
            ns_lbp::faults::WireFault::Delay(slots) => slots,
            _ => 0,
        };
        s.push_str(&format!(
            "{{\"node\":{},\"dir\":\"{}\",\"index\":{},\"fault\":\"{}\",\
             \"arg\":{}}}",
            e.node, e.dir.as_str(), e.index, e.fault.as_str(), arg
        ));
    }
    s.push_str("]}");
    s
}

/// `ns-lbp chaos --scenario NAME [--seed S] [--frames N] [--nodes N]
/// [--mix A:B:C] [--json]`: run one named, seeded fault-injection
/// scenario and report the recovery evidence against a fault-free pass
/// over the same frames.  `--json` emits one machine-readable document
/// (`BENCH_chaos.json` in CI, gated by `scripts/chaos_check.py`).
fn chaos_bench(parsed: &ns_lbp::cli::Parsed, system: SystemConfig)
               -> Result<()> {
    let scenario = parsed.opt("scenario").ok_or_else(|| {
        ns_lbp::Error::Usage(
            "chaos expects --scenario \
             flaky-transport|slow-shard|node-flap|bitflip-sweep"
                .into(),
        )
    })?;
    let json = parsed.flag("json");
    let mut system = system;
    system.fleet.nodes = parsed.opt_parse("nodes", system.fleet.nodes)?;
    system.fleet.validate()?;
    // wire/shard scenarios drive the functional backend (recovery is
    // backend-agnostic, and the fault-free logit comparison only needs
    // determinism); the bitflip sweep exercises the architectural one
    system.engine.backend = BackendKind::Functional;
    system.engine.cross_check = None;

    // one --seed steers both the fault schedule and the frame synth, so
    // "same seed, same scenario" pins the whole run
    let seed: u64 = parsed.opt_parse("seed", system.faults.seed)?;
    {
        // a named scenario owns the *injection* knobs outright (a
        // config-file stall must not pollute flaky-transport); the
        // recovery knobs (retransmit/probe/health/budget) stay tunable
        // via `[faults]` and `--set faults.*`
        let f = &mut system.faults;
        f.enabled = true;
        f.seed = seed;
        f.drop_prob = 0.0;
        f.dup_prob = 0.0;
        f.delay_prob = 0.0;
        f.flap_len = 0;
        f.stall_prob = 0.0;
        f.panic_prob = 0.0;
        f.artifact_corrupt_prob = 0.0;
        f.bitflip_sigma_scale = 1.0;
    }
    match scenario {
        "flaky-transport" => {
            let f = &mut system.faults;
            f.drop_prob = 0.04;
            f.dup_prob = 0.06;
            f.delay_prob = 0.08;
            f.delay_slots = 3;
        }
        "node-flap" => {
            let f = &mut system.faults;
            f.flap_node = 1 % system.fleet.nodes;
            f.flap_after = 20;
            f.flap_len = 60;
        }
        "slow-shard" => {
            let f = &mut system.faults;
            f.stall_prob = 0.25;
            f.stall_us = 3000;
        }
        "bitflip-sweep" => {
            return chaos_bitflip_sweep(parsed, system, seed, json);
        }
        other => {
            return Err(ns_lbp::Error::Usage(format!(
                "unknown chaos scenario {other:?} (expected \
                 flaky-transport|slow-shard|node-flap|bitflip-sweep)"
            )));
        }
    }

    let frames_n: usize = parsed.opt_parse("frames", 192)?;
    let (dataset, artifacts) = resolve_artifacts(parsed, &mut system);
    let params = match params::load(format!(
        "{artifacts}/{dataset}.params.bin"
    )) {
        Ok(p) => p,
        Err(_) => params::synth::synth_params(seed).1,
    };
    let frames = synth_frames(&params, frames_n, seed)?;
    let sensors: Vec<u32> = (0..(system.fleet.nodes as u32 * 2)).collect();
    let mix = parse_mix(parsed.opt("mix").unwrap_or("1:2:1"))?;

    if !json {
        println!(
            "chaos: {scenario} | seed {seed} | {} frames | {} nodes | \
             {} sensors",
            frames.len(), system.fleet.nodes, sensors.len()
        );
    }

    // fault-free reference pass (no plan, no monitor, same traffic)
    let mut quiet = system.clone();
    quiet.faults.enabled = false;
    let baseline = chaos_fleet_pass(&params, &quiet, &frames, &mix,
                                    &sensors, None,
                                    std::time::Duration::ZERO)?;

    // faulted pass over the wrapped transport; node-flap settles long
    // enough for 2x flap_len probe periods so the rejoin is observable
    let settle = if scenario == "node-flap" {
        let ms = (2 * system.faults.flap_len as u64
                  * system.faults.probe_ms).max(500);
        std::time::Duration::from_millis(ms)
    } else {
        std::time::Duration::ZERO
    };
    let plan = ns_lbp::faults::FaultPlan::new(system.faults.clone());
    let faulted = chaos_fleet_pass(&params, &system, &frames, &mix,
                                   &sensors, Some(&plan), settle)?;

    // completed-frame bit-identity: every (sensor, seq) both passes
    // finished must carry byte-for-byte equal logits
    let mut compared = 0u64;
    let mut divergent = 0u64;
    for (key, logits) in &faulted.logits {
        if let Some(base) = baseline.logits.get(key) {
            compared += 1;
            if base != logits {
                divergent += 1;
            }
        }
    }
    let shard_faults: u64 = faulted
        .report
        .node_reports
        .iter()
        .flatten()
        .map(|r| r.faults_injected)
        .sum();
    use std::sync::atomic::Ordering as ChaosOrd;
    let (dropped, duplicated, delayed, blackholed) = (
        plan.ledger.dropped.load(ChaosOrd::Relaxed),
        plan.ledger.duplicated.load(ChaosOrd::Relaxed),
        plan.ledger.delayed.load(ChaosOrd::Relaxed),
        plan.ledger.blackholed.load(ChaosOrd::Relaxed),
    );
    let budget = system.faults.p99_budget;
    let within = faulted.report.p99_ms <= budget;

    if json {
        let mut s = format!(
            "{{\"scenario\":\"{scenario}\",\"seed\":{seed},\"frames\":{},\
             \"nodes\":{},",
            frames.len(),
            system.fleet.nodes
        );
        s.push_str(&format!("\"faults\":{},", faults_json(&system.faults)));
        s.push_str(&format!(
            "\"schedule\":{},",
            schedule_json(&plan, system.fleet.nodes)
        ));
        s.push_str(&format!(
            "\"baseline\":{{\"completed\":{},\"p99_ms\":{},\
             \"admission_retries\":{}}},",
            baseline.report.completed, baseline.report.p99_ms,
            baseline.admission_retries
        ));
        s.push_str(&format!(
            "\"faulted\":{{\"admission_retries\":{},\
             \"wire\":{{\"dropped\":{dropped},\"duplicated\":{duplicated},\
             \"delayed\":{delayed},\"blackholed\":{blackholed}}},\
             \"shard_faults\":{shard_faults},\"report\":{}}},",
            faulted.admission_retries,
            faulted.report.to_json()
        ));
        s.push_str(&format!(
            "\"divergence\":{{\"compared\":{compared},\
             \"divergent\":{divergent}}},"
        ));
        s.push_str(&format!(
            "\"gates\":{{\"p99_budget_ms\":{budget},\
             \"recovery_p99_ms\":{},\"within_budget\":{within},\
             \"billed_lost\":{},\"orphaned\":{},\"deduped\":{},\
             \"retries\":{}}}}}",
            faulted.report.p99_ms,
            faulted.report.billed_lost(),
            faulted.report.orphaned,
            faulted.report.deduped,
            faulted.report.retries
        ));
        println!("{s}");
    } else {
        baseline.report.print("fault-free");
        faulted.report.print("faulted");
        println!(
            "  injected  : {} wire ({dropped} dropped, {duplicated} dup, \
             {delayed} delayed, {blackholed} blackholed) | {shard_faults} \
             shard",
            dropped + duplicated + delayed + blackholed
        );
        println!(
            "  chaos gate: billed lost {} | orphaned {} | divergent {}/{} \
             | recovery p99 {:.3} ms (budget {:.1} ms{}) | retransmits {} \
             | deduped {}",
            faulted.report.billed_lost(),
            faulted.report.orphaned,
            divergent,
            compared,
            faulted.report.p99_ms,
            budget,
            if within { "" } else { " EXCEEDED" },
            faulted.report.retries,
            faulted.report.deduped
        );
    }
    Ok(())
}

/// The comparator-variation sweep: rerun the same frames through the
/// architectural backend at increasing `bitflip_sigma_scale` and report
/// the Monte-Carlo flip rate, flips actually injected, and logit
/// divergence against the nominal (fault-free) pass.  Rates and flip
/// sets are deterministic in the seed, and flip sets at a lower scale
/// are subsets of those at a higher one, so divergence is monotone.
fn chaos_bitflip_sweep(parsed: &ns_lbp::cli::Parsed, mut system: SystemConfig,
                       seed: u64, json: bool) -> Result<()> {
    let frames_n: usize = parsed.opt_parse("frames", 24)?;
    let (dataset, artifacts) = resolve_artifacts(parsed, &mut system);
    let params = match params::load(format!(
        "{artifacts}/{dataset}.params.bin"
    )) {
        Ok(p) => p,
        Err(_) => params::synth::synth_params(seed).1,
    };
    let frames = synth_frames(&params, frames_n, seed)?;

    let build = |sys: &SystemConfig| -> Result<Engine> {
        Engine::builder()
            .config(CoordinatorConfig {
                system: sys.clone(),
                arch: ArchSim { lbp: true, mlp: false, early_exit: false },
                shard: None,
            })
            .params(params.clone())
            .backend(BackendKind::Architectural)
            .no_cross_check()
            .build()
    };

    let mut quiet = system.clone();
    quiet.faults.enabled = false;
    let mut engine = build(&quiet)?;
    let base_out = engine.infer_batch(&frames)?;

    // nominal sigma must be error-free (the paper's operating point)
    let mut nominal = system.clone();
    nominal.faults.bitflip_sigma_scale = 1.0;
    let nominal_rate = ns_lbp::faults::BitFlips::rate_for(
        &nominal.faults, &nominal.circuit);

    struct SweepPoint {
        scale: f64,
        rate: f64,
        flips: u64,
        divergent: u64,
        arch_mismatches: u64,
    }
    let scales = [4.0f64, 8.0, 16.0, 32.0];
    let mut points: Vec<SweepPoint> = Vec::with_capacity(scales.len());
    for &scale in &scales {
        let mut sys = system.clone();
        sys.faults.enabled = true;
        sys.faults.bitflip_sigma_scale = scale;
        let rate =
            ns_lbp::faults::BitFlips::rate_for(&sys.faults, &sys.circuit);
        let before = ns_lbp::faults::bitflips_injected();
        let mut e = build(&sys)?;
        let out = e.infer_batch(&frames)?;
        let flips = ns_lbp::faults::bitflips_injected() - before;
        let mut divergent = 0u64;
        let mut arch_mismatches = 0u64;
        for (b, o) in base_out.frames.iter().zip(&out.frames) {
            if b.logits != o.logits {
                divergent += 1;
            }
            arch_mismatches += o.telemetry.arch_mismatches;
        }
        points.push(SweepPoint { scale, rate, flips, divergent,
                                 arch_mismatches });
    }
    let rates_monotone =
        points.windows(2).all(|w| w[0].rate <= w[1].rate);
    let flips_monotone =
        points.windows(2).all(|w| w[0].flips <= w[1].flips);
    let divergence_monotone =
        points.windows(2).all(|w| w[0].divergent <= w[1].divergent);

    if json {
        let plan = ns_lbp::faults::FaultPlan::new(system.faults.clone());
        let mut s = format!(
            "{{\"scenario\":\"bitflip-sweep\",\"seed\":{seed},\
             \"frames\":{},\"nodes\":1,",
            frames.len()
        );
        s.push_str(&format!("\"faults\":{},", faults_json(&system.faults)));
        s.push_str(&format!("\"schedule\":{},", schedule_json(&plan, 1)));
        s.push_str("\"sweep\":[");
        for (i, p) in points.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "{{\"sigma_scale\":{},\"rate\":{},\"bitflips\":{},\
                 \"compared\":{},\"divergent\":{},\"arch_mismatches\":{}}}",
                p.scale, p.rate, p.flips, frames.len(), p.divergent,
                p.arch_mismatches
            ));
        }
        s.push_str("],");
        s.push_str(&format!(
            "\"gates\":{{\"nominal_rate\":{nominal_rate},\
             \"rates_monotone\":{rates_monotone},\
             \"flips_monotone\":{flips_monotone},\
             \"divergence_monotone\":{divergence_monotone}}}}}"
        ));
        println!("{s}");
    } else {
        println!(
            "chaos: bitflip-sweep | seed {seed} | {} frames | nominal \
             rate {nominal_rate:.3e}",
            frames.len()
        );
        for p in &points {
            println!(
                "  sigma x{:<4} : rate {:.3e} | {} flips | {}/{} frames \
                 divergent | {} arch mismatches",
                p.scale, p.rate, p.flips, p.divergent, frames.len(),
                p.arch_mismatches
            );
        }
        println!(
            "  chaos gate: rates monotone {rates_monotone} | flips \
             monotone {flips_monotone} | divergence monotone \
             {divergence_monotone}"
        );
    }
    Ok(())
}

/// `ns-lbp trace FEED.jsonl [FEED2.jsonl …] [--json] [--chrome OUT]`:
/// summarize one or more trace feeds captured with `serve-bench --trace`
/// or `fleet-bench --trace` — per-stage latency percentiles, energy by
/// stage, per-class outcomes, and drop causes, from the spans alone.
/// Several feeds (e.g. a fleet's per-node files) merge into one summary;
/// `--chrome` additionally writes a merged Chrome trace with one process
/// per feed.
fn trace_summary(parsed: &ns_lbp::cli::Parsed) -> Result<()> {
    if parsed.positionals.is_empty() {
        return Err(ns_lbp::Error::Usage(
            "trace expects one or more feed paths: ns-lbp trace \
             TRACE.jsonl [MORE.jsonl ...] [--json] [--chrome OUT]"
                .into(),
        ));
    }
    let mut contents: Vec<(&str, String)> = Vec::new();
    for path in &parsed.positionals {
        let feed = std::fs::read_to_string(path).map_err(|e| {
            ns_lbp::Error::Config(format!("cannot read {path}: {e}"))
        })?;
        contents.push((path.as_str(), feed));
    }
    let named: Vec<(&str, &str)> =
        contents.iter().map(|(p, f)| (*p, f.as_str())).collect();
    let summary = ns_lbp::obs::summarize_feeds(&named)?;
    if parsed.flag("json") {
        println!("{}", summary.to_json());
    } else {
        print!("{}", summary.render());
    }
    if let Some(out) = parsed.opt("chrome") {
        let n = ns_lbp::obs::merge_chrome_trace(&named, out)?;
        if !parsed.flag("json") {
            println!(
                "\nchrome: {n} events from {} feed(s) → {out}",
                named.len()
            );
        }
    }
    Ok(())
}

/// `ns-lbp ab --profile A --profile B`: the ROADMAP A/B energy harness —
/// run the same synthetic frames through two architectural engines under
/// two hardware profiles and print (or `--json`-emit) the diff report.
fn ab_compare(parsed: &ns_lbp::cli::Parsed, mut system: SystemConfig)
              -> Result<()> {
    let specs = parsed.opt_all("profile");
    if specs.len() != 2 {
        return Err(ns_lbp::Error::Usage(format!(
            "ab expects exactly two --profile options (got {}), e.g. \
             --profile ns_lbp_65nm --profile sram38_28nm",
            specs.len()
        )));
    }
    let a = HwProfile::resolve(&specs[0])?;
    let b = HwProfile::resolve(&specs[1])?;
    let frames_n: usize = parsed.opt_parse("frames", 8)?;
    let seed: u64 = parsed.opt_parse("seed", 7)?;
    let json = parsed.flag("json");

    let (dataset, artifacts) = resolve_artifacts(parsed, &mut system);
    let params = match params::load(format!("{artifacts}/{dataset}.params.bin")) {
        Ok(p) => p,
        Err(_) => params::synth::synth_params(seed).1,
    };
    let arch = ArchSim {
        lbp: !parsed.flag("functional"),
        mlp: parsed.flag("arch-mlp"),
        early_exit: parsed.flag("early-exit"),
    };
    let frames = synth_frames(&params, frames_n, seed)?;
    let harness = AbHarness::new(
        params,
        CoordinatorConfig { system, arch, shard: None },
        a,
        b,
    )?;
    let report = harness.run(&frames)?;
    if json {
        println!("{}", report.to_json());
    } else {
        report.print();
    }
    Ok(())
}

/// `ns-lbp profile [NAME]`: print a hardware profile as a standalone
/// TOML file (the `configs/profiles/*.toml` format; redirect to a file
/// to snapshot or fork a profile).  NAME may also come from
/// `--hw-profile`; with neither, list the built-in profile names so the
/// subcommand is self-documenting.
fn dump_profile(parsed: &ns_lbp::cli::Parsed, system: &SystemConfig)
                -> Result<()> {
    if let Some(name) = parsed.positionals.first() {
        print!("{}", HwProfile::resolve(name)?.to_toml());
    } else if parsed.opt("hw-profile").is_some() {
        print!("{}", system.hw.profile.to_toml());
    } else {
        println!("built-in hardware profiles (ns-lbp profile NAME):");
        for name in ns_lbp::hw::BUILTIN_PROFILES {
            println!("  {name}");
        }
    }
    Ok(())
}

/// `ns-lbp compile SPEC.toml [--out-dir D] [--cache-dir D] [--json]
/// [--check]`: lower a model spec through the staged pipeline into a
/// versioned on-disk artifact.  `--check` reloads the artifact from disk
/// and proves engines built from its prepacked tables reproduce
/// from-params engines exactly — bit-identical logits and identical
/// modeled cost — on both backends.
fn compile_model(parsed: &ns_lbp::cli::Parsed, system: SystemConfig)
                 -> Result<()> {
    let spec_path = parsed.positionals.first().ok_or_else(|| {
        ns_lbp::Error::Usage(
            "compile expects the spec path: ns-lbp compile SPEC.toml \
             [--out-dir DIR] [--cache-dir DIR] [--json] [--check]"
                .into(),
        )
    })?;
    let spec = ModelSpec::load(spec_path)?;
    let mut opts = CompileOptions::from_system(&system);
    if let Some(dir) = parsed.opt("out-dir") {
        opts.out_dir = dir.into();
    }
    if let Some(dir) = parsed.opt("cache-dir") {
        opts.cache_dir = dir.into();
    }
    let (model, report) = ns_lbp::compile::compile(&spec, &system, &opts)?;
    if parsed.flag("json") {
        println!("{}", report.to_json());
    } else {
        report.print();
    }
    if parsed.flag("check") {
        check_artifact(&report.path, model.version, &system, parsed.flag("json"))?;
    }
    Ok(())
}

/// The `compile --check` gate: reload the artifact at `path` and assert
/// that, for both backends, an engine fed its prepacked tables produces
/// bit-identical logits and identical modeled cost to an engine that
/// packs the same parameters from scratch.
fn check_artifact(path: &std::path::Path, version: u64,
                  system: &SystemConfig, json: bool) -> Result<()> {
    let loaded = CompiledModel::load(path)?;
    if loaded.version != version {
        return Err(ns_lbp::Error::Engine(format!(
            "reloaded artifact version {:016x} does not match the compile \
             output {version:016x}",
            loaded.version
        )));
    }
    let frames = synth_frames(&loaded.params, 4, 23)?;
    let arch = ArchSim { lbp: true, mlp: true, early_exit: false };
    for kind in [BackendKind::Functional, BackendKind::Architectural] {
        let config = CoordinatorConfig {
            system: system.clone(),
            arch,
            shard: None,
        };
        let mut from_params = Engine::builder()
            .config(config.clone())
            .params(loaded.params.clone())
            .backend(kind)
            .no_cross_check()
            .build()?;
        let mut from_artifact = Engine::builder()
            .config(config)
            .params(loaded.params.clone())
            .backend(kind)
            .no_cross_check()
            .prepacked(std::sync::Arc::new(loaded.prepacked()))
            .build()?;
        let want = from_params.infer_batch(&frames)?;
        let got = from_artifact.infer_batch(&frames)?;
        for (w, g) in want.frames.iter().zip(&got.frames) {
            if w.logits != g.logits || w.predicted != g.predicted {
                return Err(ns_lbp::Error::Engine(format!(
                    "check failed: {kind} engine from the artifact diverged \
                     from the from-params engine on frame {}",
                    w.seq
                )));
            }
        }
        let (tw, tg) = (want.telemetry(), got.telemetry());
        if tw.cost.energy.total_pj() != tg.cost.energy.total_pj()
            || tw.cost.time_ns != tg.cost.time_ns
            || tw.exec.instructions != tg.exec.instructions
        {
            return Err(ns_lbp::Error::Engine(format!(
                "check failed: {kind} engine from the artifact priced \
                 differently from the from-params engine"
            )));
        }
        if !json {
            println!(
                "check {kind}: {} frames bit-identical \
                 ({:.3} µJ/frame, {} instrs)",
                frames.len(),
                tw.cost.energy.total_pj() / 1e6 / frames.len() as f64,
                tw.exec.instructions
            );
        }
    }
    Ok(())
}

fn transient(system: SystemConfig) -> Result<()> {
    let p = system.circuit;
    p.validate()?;
    println!("RBL transients (VDD {} V, sense at {} ps):", p.vdd, SENSE_DELAY_PS);
    println!("{:>8} {:>9} {:>9} {:>9} {:>9}", "t[ps]", "\"000\"", "\"001\"",
             "\"011\"", "\"111\"");
    let mut t = 0.0;
    while t <= 800.0 {
        let row: Vec<String> = (0..4)
            .map(|ones| format!("{:9.3}", p.rbl_waveform(ones, t).unwrap()))
            .collect();
        println!("{t:>8.0} {}", row.join(" "));
        t += 80.0;
    }
    let [r1, r2, r3] = p.refs();
    println!("references: V_R1={r1:.3} V_R2={r2:.3} V_R3={r3:.3}");
    Ok(())
}

fn montecarlo(parsed: &ns_lbp::cli::Parsed, system: SystemConfig) -> Result<()> {
    let trials: usize = parsed.opt_parse("trials", 200)?;
    let seed: u64 = parsed.opt_parse("seed", 7)?;
    let mut mc = MonteCarlo::new(system.circuit);
    mc.trials = trials;
    let r = mc.run(seed);
    println!("Monte-Carlo: {} trials x {} bit-lines", r.trials, r.bitlines);
    for (i, lv) in r.levels.iter().enumerate() {
        println!(
            "  level {i} ('{}'): mean {:.3} V std {:.1} mV [{:.3}, {:.3}]",
            "0".repeat(3 - i) + &"1".repeat(i),
            lv.mean, lv.std * 1e3, lv.min, lv.max
        );
    }
    for (i, g) in r.level_gaps.iter().enumerate() {
        println!("  gap {i}-{}: {:.1} mV", i + 1, g * 1e3);
    }
    println!(
        "  min margin {:.1} mV | decision errors {:.2e}",
        r.min_margin * 1e3,
        r.decision_error_rate
    );
    Ok(())
}

fn info(system: SystemConfig) -> Result<()> {
    let g = system.cache;
    let profile = system.hw_profile();
    println!("NS-LBP v{}", ns_lbp::VERSION);
    println!(
        "cache: {} banks x {} mats x {} sub-arrays ({}x{}) = {:.1} MB",
        g.banks, g.mats_per_bank, g.subarrays_per_mat, g.rows, g.cols,
        g.total_bytes() as f64 / (1024.0 * 1024.0)
    );
    println!(
        "circuit: VDD {} V, {} GHz, refs {:?} V",
        system.circuit.vdd, system.circuit.freq_ghz, system.circuit.refs()
    );
    println!(
        "engine: backend {} (set with --backend or --set engine.backend=...)",
        engine_banner(&system)
    );
    println!(
        "hw profile: {} ({} GHz; swap with --hw-profile or [hw] profile)",
        profile.name, profile.energy.freq_ghz
    );
    println!(
        "headline: {:.1} TOPS/W peak, {:.1} TOPS, {:.2} mm² slice, \
         SA overhead {:.1}x",
        profile.tops_per_watt(g.cols as u64),
        profile.peak_tops(&g),
        profile.area_mm2(&g),
        profile.area.sa_overhead
    );
    Ok(())
}
