//! Hand-rolled configuration system (serde/toml are unavailable offline).
//!
//! Parses a pragmatic TOML subset — `[section]` headers, `key = value` with
//! string / integer / float / bool / homogeneous-array values, `#` comments —
//! into a [`ConfigFile`] with typed, error-reporting accessors, and maps it
//! onto the NS-LBP system configuration [`SystemConfig`] (cache geometry,
//! circuit calibration, sensor and network settings).
//!
//! The default configuration reproduces the paper's setup exactly
//! (2.5 MB slice, 80×32 KB banks, 256×256 sub-arrays, 65 nm @ 1.1 V,
//! 1.25 GHz); `configs/nslbp_default.toml` spells it out and any field can
//! be overridden from a user file or `--set section.key=value` CLI options.

use std::collections::BTreeMap;
use std::path::Path;

use crate::engine::{BackendKind, QosClass, RoutingPolicy};
use crate::error::{Error, Result};
use crate::hw::HwProfile;

/// A parsed scalar or array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    fn type_name(&self) -> &'static str {
        match self {
            Value::Str(_) => "string",
            Value::Int(_) => "integer",
            Value::Float(_) => "float",
            Value::Bool(_) => "bool",
            Value::Array(_) => "array",
        }
    }
}

/// Parsed config file: `section.key -> Value` (root section is `""`).
#[derive(Clone, Debug, Default)]
pub struct ConfigFile {
    entries: BTreeMap<String, Value>,
}

impl ConfigFile {
    /// Parse from text. Line-oriented; errors carry line numbers.
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| err_at(lineno, "unterminated [section]"))?;
                section = name.trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| err_at(lineno, "expected key = value"))?;
            let full_key = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            let value = parse_value(val.trim())
                .map_err(|e| err_at(lineno, &format!("bad value for {full_key}: {e}")))?;
            entries.insert(full_key, value);
        }
        Ok(Self { entries })
    }

    /// Parse from a file path.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref()).map_err(|e| {
            Error::Config(format!("cannot read {}: {e}", path.as_ref().display()))
        })?;
        Self::parse(&text)
    }

    /// Apply a `section.key=value` override (CLI `--set`).
    pub fn set_override(&mut self, spec: &str) -> Result<()> {
        let (key, val) = spec
            .split_once('=')
            .ok_or_else(|| Error::Config(format!("--set expects k=v, got {spec:?}")))?;
        let value = parse_value(val.trim())
            .map_err(|e| Error::Config(format!("bad value in --set {spec:?}: {e}")))?;
        self.entries.insert(key.trim().to_string(), value);
        Ok(())
    }

    pub fn contains(&self, key: &str) -> bool {
        self.entries.contains_key(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }

    fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn get_i64(&self, key: &str, default: i64) -> Result<i64> {
        match self.get(key) {
            None => Ok(default),
            Some(Value::Int(v)) => Ok(*v),
            Some(other) => Err(type_err(key, "integer", other)),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        let v = self.get_i64(key, default as i64)?;
        usize::try_from(v)
            .map_err(|_| Error::Config(format!("{key} must be non-negative, got {v}")))
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(Value::Float(v)) => Ok(*v),
            Some(Value::Int(v)) => Ok(*v as f64),
            Some(other) => Err(type_err(key, "float", other)),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some(Value::Bool(v)) => Ok(*v),
            Some(other) => Err(type_err(key, "bool", other)),
        }
    }

    pub fn get_str(&self, key: &str, default: &str) -> Result<String> {
        match self.get(key) {
            None => Ok(default.to_string()),
            Some(Value::Str(v)) => Ok(v.clone()),
            Some(other) => Err(type_err(key, "string", other)),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in trimmed.split(',') {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Array(items));
    }
    // underscores as digit separators, like real TOML
    let cleaned = s.replace('_', "");
    if let Ok(v) = cleaned.parse::<i64>() {
        return Ok(Value::Int(v));
    }
    if let Ok(v) = cleaned.parse::<f64>() {
        return Ok(Value::Float(v));
    }
    Err(format!("cannot parse {s:?}"))
}

fn err_at(lineno: usize, msg: &str) -> Error {
    Error::Config(format!("line {}: {msg}", lineno + 1))
}

fn type_err(key: &str, want: &str, got: &Value) -> Error {
    Error::Config(format!("{key}: expected {want}, got {}", got.type_name()))
}

// ---------------------------------------------------------------------------
// System configuration
// ---------------------------------------------------------------------------

/// Engine-layer backend selection (see [`crate::engine`]): which
/// [`BackendKind`] executes inference, and an optional reference backend
/// every frame is cross-checked against (logit divergences are counted in
/// the telemetry).  Settable from the `[engine]` config section or
/// `--set engine.backend=functional` / `--set engine.cross_check=...`;
/// the CLI `--backend` / `--cross-check` options override both.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EngineSelection {
    /// Primary inference backend (default: architectural).
    pub backend: BackendKind,
    /// Reference backend for per-frame cross-checking (default: none).
    pub cross_check: Option<BackendKind>,
    /// HLO artifact the PJRT backend executes, resolved inside
    /// `artifacts_dir` (the CLI derives `aplbp_<dataset>` from
    /// `--dataset`).
    pub pjrt_artifact: String,
    /// Per-QoS-class backend routing (`[engine.routing]`, `--route`);
    /// unrouted classes run on `backend`.
    pub routing: RoutingPolicy,
}

impl Default for EngineSelection {
    fn default() -> Self {
        Self {
            backend: BackendKind::default(),
            cross_check: None,
            pjrt_artifact: "aplbp_mnist".into(),
            routing: RoutingPolicy::default(),
        }
    }
}

/// Per-QoS-class overrides of the `[serve]` defaults, written as
/// `[serve.best_effort]` / `[serve.standard]` / `[serve.billed]`
/// sections.  Unset fields fall back to the class-independent knobs
/// (except `drop_oldest`, whose default is class-dependent: sensor-style
/// best-effort traffic prefers fresh frames).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClassPolicy {
    /// Admission depth for this class's queue.
    pub queue_depth: Option<usize>,
    /// Batch-size trigger for this class's batcher.
    pub max_batch: Option<usize>,
    /// Batch-deadline trigger for this class's batcher [µs].
    pub deadline_us: Option<u64>,
    /// Full queue: displace the oldest queued request (true) or reject
    /// the new one (false).
    pub drop_oldest: Option<bool>,
}

/// Fully resolved per-class serving knobs (see
/// [`ServeConfig::class_knobs`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClassKnobs {
    pub queue_depth: usize,
    pub max_batch: usize,
    pub deadline_us: u64,
    pub drop_oldest: bool,
}

impl ClassKnobs {
    pub fn deadline(&self) -> std::time::Duration {
        std::time::Duration::from_micros(self.deadline_us)
    }
}

/// Hardware cost-model selection (see [`crate::hw`]): which
/// [`HwProfile`] prices telemetry, picked by name (a built-in) or by
/// path (a `configs/profiles/*.toml` file) via `[hw] profile = "..."`,
/// with optional flat field overrides (`hw.freq_ghz = 0.5`,
/// `hw.energy_scale = 2.0`, any [`crate::hw::ENERGY_FIELDS`] /
/// [`crate::hw::AREA_FIELDS`] name).  The CLI `--hw-profile` overrides
/// the file.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HwSelection {
    /// The selected profile (default: `ns_lbp_65nm`, the paper's point).
    pub profile: HwProfile,
    /// True when the config explicitly set `hw.freq_ghz` — an explicit
    /// hw-side clock always wins over `[circuit] freq_ghz`, even when it
    /// equals the stock value (see [`SystemConfig::hw_profile`]).
    pub clock_explicit: bool,
}

/// Async serve-plane knobs, written as `[serve.async]` (see
/// [`crate::serve::async_plane`] and [`crate::exec`]).  When `enabled`,
/// the server multiplexes per-sensor sessions onto a small executor
/// worker pool instead of spawning a thread per batcher/shard, applies
/// deficit-round-robin fairness across sensors within each QoS class,
/// and autoscales the active engine-shard count between `min_shards`
/// and `max_shards` under offered load.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AsyncServeConfig {
    /// Run the serve plane on the executor instead of dedicated threads.
    pub enabled: bool,
    /// Executor worker threads (0 = one per available core, capped at 8).
    pub workers: usize,
    /// Floor of the autoscaled engine-shard range.
    pub min_shards: usize,
    /// Ceiling of the autoscaled range (0 = follow `serve.shards`).
    pub max_shards: usize,
    /// DRR quantum: frames one sensor may dequeue per ring visit.
    pub quantum: u32,
    /// Scale up when queued batches per active shard reach this depth.
    pub scale_up_depth: usize,
    /// Scale down after this many consecutive idle load samples.
    pub scale_down_idle: u32,
    /// Autoscaler sampling period [µs].
    pub scale_interval_us: u64,
}

impl Default for AsyncServeConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            workers: 0,
            min_shards: 1,
            max_shards: 0,
            quantum: 4,
            scale_up_depth: 2,
            scale_down_idle: 8,
            scale_interval_us: 1000,
        }
    }
}

impl AsyncServeConfig {
    /// The effective autoscale ceiling: an explicit `max_shards`, else
    /// the thread-plane `serve.shards` count.
    pub fn max_shards_or(&self, shards: usize) -> usize {
        if self.max_shards == 0 { shards } else { self.max_shards }
    }

    pub fn scale_interval(&self) -> std::time::Duration {
        std::time::Duration::from_micros(self.scale_interval_us.max(1))
    }
}

/// Frame-serving subsystem knobs (see [`crate::serve`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeConfig {
    /// Shard workers; each owns a disjoint bank slice of the cache.
    pub shards: usize,
    /// Admission-control bound: requests beyond this depth are rejected.
    pub queue_depth: usize,
    /// Dispatch a batch once it reaches this many frames ...
    pub max_batch: usize,
    /// ... or once the oldest queued frame is this old [µs].
    pub batch_deadline_us: u64,
    /// Per-shard LRU capacity for engines built from pushed model
    /// artifacts (the default model's engines are pinned and never
    /// evicted; this bounds the rest).
    pub model_cache: usize,
    /// Per-class overrides, indexed by [`QosClass::index`].
    pub classes: [ClassPolicy; QosClass::COUNT],
    /// Async serve-plane knobs (`[serve.async]`).
    pub async_plane: AsyncServeConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { shards: 4, queue_depth: 256, max_batch: 16,
               batch_deadline_us: 2000, model_cache: 4,
               classes: [ClassPolicy::default(); QosClass::COUNT],
               async_plane: AsyncServeConfig::default() }
    }
}

impl ServeConfig {
    pub fn validate(&self) -> Result<()> {
        if self.shards == 0 {
            return Err(Error::Config("serve.shards must be >= 1".into()));
        }
        if self.queue_depth == 0 {
            return Err(Error::Config("serve.queue_depth must be >= 1".into()));
        }
        if self.max_batch == 0 {
            return Err(Error::Config("serve.max_batch must be >= 1".into()));
        }
        if self.model_cache == 0 {
            return Err(Error::Config("serve.model_cache must be >= 1".into()));
        }
        for class in QosClass::ALL {
            let k = self.class_knobs(class);
            if k.queue_depth == 0 {
                return Err(Error::Config(format!(
                    "serve.{}.queue_depth must be >= 1", class
                )));
            }
            if k.max_batch == 0 {
                return Err(Error::Config(format!(
                    "serve.{}.max_batch must be >= 1", class
                )));
            }
        }
        let a = &self.async_plane;
        if a.min_shards == 0 {
            return Err(Error::Config(
                "serve.async.min_shards must be >= 1".into(),
            ));
        }
        let max = a.max_shards_or(self.shards);
        if max < a.min_shards {
            return Err(Error::Config(format!(
                "serve.async.max_shards ({max}) must be >= \
                 serve.async.min_shards ({})", a.min_shards
            )));
        }
        if a.quantum == 0 {
            return Err(Error::Config(
                "serve.async.quantum must be >= 1".into(),
            ));
        }
        if a.scale_up_depth == 0 {
            return Err(Error::Config(
                "serve.async.scale_up_depth must be >= 1".into(),
            ));
        }
        if a.scale_down_idle == 0 {
            return Err(Error::Config(
                "serve.async.scale_down_idle must be >= 1".into(),
            ));
        }
        Ok(())
    }

    pub fn batch_deadline(&self) -> std::time::Duration {
        std::time::Duration::from_micros(self.batch_deadline_us)
    }

    /// Resolve the effective knobs for one class: explicit per-class
    /// values win, then the class-independent `[serve]` defaults.
    /// `drop_oldest` defaults to true only for best-effort (always-on
    /// sensor streams prefer fresh frames over queue completeness).
    pub fn class_knobs(&self, class: QosClass) -> ClassKnobs {
        let p = self.classes[class.index()];
        ClassKnobs {
            queue_depth: p.queue_depth.unwrap_or(self.queue_depth),
            max_batch: p.max_batch.unwrap_or(self.max_batch),
            deadline_us: p.deadline_us.unwrap_or(self.batch_deadline_us),
            drop_oldest: p
                .drop_oldest
                .unwrap_or(class == QosClass::BestEffort),
        }
    }
}

/// Complete NS-LBP system configuration (paper defaults).
#[derive(Clone, Debug, PartialEq)]
pub struct SystemConfig {
    pub cache: crate::sram::CacheGeometry,
    pub circuit: crate::circuit::CircuitParams,
    pub sensor: crate::sensor::SensorConfig,
    /// Frame-serving subsystem knobs.
    pub serve: ServeConfig,
    /// Multi-node fleet knobs (see [`crate::fleet`]).
    pub fleet: FleetConfig,
    /// Fault-injection / recovery knobs (see [`crate::faults`]).
    pub faults: FaultsConfig,
    /// Engine-layer backend selection.
    pub engine: EngineSelection,
    /// Hardware cost-model selection.
    pub hw: HwSelection,
    /// Trace/observability pipeline knobs (see [`crate::obs`]).
    pub obs: crate::obs::ObsConfig,
    /// Model-compilation directories (see [`crate::compile`]).
    pub compile: CompileDirs,
    /// Worker threads for the coordinator (0 = one per bank group).
    pub workers: usize,
    /// Artifacts directory for HLO/params files.
    pub artifacts_dir: String,
}

/// Fleet-layer knobs (`[fleet]` section — see [`crate::fleet`]): node
/// count, per-node per-class admission capacity, and the failure-drill
/// parameters `ns-lbp fleet-bench --drill` runs with.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetConfig {
    /// Serve nodes the fleet starts.
    pub nodes: usize,
    /// Per-node in-flight admission capacity, per class
    /// ([`QosClass::index`] order).  The router spills past a sensor's
    /// rendezvous owner when the owner is full, and rejects (retryably)
    /// when every live node is.
    pub capacity: [usize; QosClass::COUNT],
    pub drill: DrillKnobs,
}

/// Failure-drill parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DrillKnobs {
    /// Which node the drill kills.
    pub kill_node: usize,
    /// Kill after this many completed frames (0 = halfway through the
    /// offered load).
    pub kill_after: usize,
    /// Drill gate: the killed-node run's router-observed p99 must stay
    /// within this factor of the undisturbed baseline's p99.  Generous
    /// by default — it is a sanity bound on re-homing, not a perf SLO
    /// (CI boxes are noisy and the loads are tiny).
    pub p99_budget: f64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            nodes: 3,
            capacity: [64; QosClass::COUNT],
            drill: DrillKnobs::default(),
        }
    }
}

impl Default for DrillKnobs {
    fn default() -> Self {
        Self { kill_node: 1, kill_after: 0, p99_budget: 50.0 }
    }
}

impl FleetConfig {
    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 {
            return Err(Error::Config("fleet.nodes must be >= 1".into()));
        }
        for class in QosClass::ALL {
            if self.capacity[class.index()] == 0 {
                return Err(Error::Config(format!(
                    "fleet.capacity.{class} must be >= 1"
                )));
            }
        }
        if self.drill.kill_node >= self.nodes {
            return Err(Error::Config(format!(
                "fleet.drill.kill_node {} out of range (fleet has {} nodes)",
                self.drill.kill_node, self.nodes
            )));
        }
        if !(self.drill.p99_budget > 0.0) {
            return Err(Error::Config(
                "fleet.drill.p99_budget must be > 0".into(),
            ));
        }
        Ok(())
    }
}

/// Fault-injection and recovery knobs (`[faults]` section — see
/// [`crate::faults`]).  Everything is seeded and deterministic: the same
/// `seed` yields the same fault schedule, so chaos drills are
/// reproducible.  Probabilities are per-decision (per wire message, per
/// shard dispatch, per artifact load); the node-flap window counts
/// messages, not wall time, so the blackhole is schedule-stable too.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultsConfig {
    /// Master switch: when false no fault is ever injected and no
    /// health-monitor thread is spawned.
    pub enabled: bool,
    /// Seed for every fault draw (`ns-lbp chaos --seed` overrides).
    pub seed: u64,
    /// Transport: drop a wire message.
    pub drop_prob: f64,
    /// Transport: duplicate a wire message.
    pub dup_prob: f64,
    /// Transport: hold a wire message back (delivered out of order).
    pub delay_prob: f64,
    /// How many later sends a held message waits behind (count-space
    /// delay, so the schedule stays deterministic).
    pub delay_slots: usize,
    /// Node-flap: node whose links black-hole for a message-count window.
    pub flap_node: usize,
    /// Node-flap: window starts after this many messages on the link.
    pub flap_after: usize,
    /// Node-flap: window length in messages (0 = no flap).
    pub flap_len: usize,
    /// Shard: probability a dispatch stalls for `stall_us`.
    pub stall_prob: f64,
    /// Shard: injected stall length [µs].
    pub stall_us: u64,
    /// Shard: probability a dispatch panics (at most one injected panic
    /// per process — a crash does not resurrect).
    pub panic_prob: f64,
    /// Probability a pushed `.nslbpc` artifact is corrupted in transit
    /// (one flipped byte; the artifact checksum must catch it).
    pub artifact_corrupt_prob: f64,
    /// Comparator bit-flips: scale factor on the circuit variation
    /// sigmas; the flip rate is the Monte-Carlo decision-error rate at
    /// the scaled sigma (1.0 = nominal, which the paper shows is
    /// error-free).
    pub bitflip_sigma_scale: f64,
    /// Router: re-home a pending frame this old [ms].
    pub retransmit_ms: u64,
    /// Health monitor: ping period [ms].
    pub probe_ms: u64,
    /// Health: a node silent this long is suspect [ms].
    pub suspect_ms: u64,
    /// Health: a node silent this long is dead (re-homed) [ms].
    pub dead_ms: u64,
    /// Degrade a Standard submit to BestEffort after this many
    /// consecutive admission failures (0 = never degrade).
    pub degrade_after: u64,
    /// Chaos gate: recovery p99 must stay under this bound [ms].
    pub p99_budget: f64,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            seed: 42,
            drop_prob: 0.0,
            dup_prob: 0.0,
            delay_prob: 0.0,
            delay_slots: 2,
            flap_node: 0,
            flap_after: 0,
            flap_len: 0,
            stall_prob: 0.0,
            stall_us: 2000,
            panic_prob: 0.0,
            artifact_corrupt_prob: 0.0,
            bitflip_sigma_scale: 1.0,
            retransmit_ms: 250,
            probe_ms: 25,
            suspect_ms: 100,
            dead_ms: 300,
            degrade_after: 3,
            p99_budget: 1500.0,
        }
    }
}

impl FaultsConfig {
    pub fn validate(&self) -> Result<()> {
        for (key, p) in [
            ("faults.drop_prob", self.drop_prob),
            ("faults.dup_prob", self.dup_prob),
            ("faults.delay_prob", self.delay_prob),
            ("faults.stall_prob", self.stall_prob),
            ("faults.panic_prob", self.panic_prob),
            ("faults.artifact_corrupt_prob", self.artifact_corrupt_prob),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(Error::Config(format!(
                    "{key} must be in [0, 1], got {p}"
                )));
            }
        }
        if !(self.bitflip_sigma_scale > 0.0) {
            return Err(Error::Config(
                "faults.bitflip_sigma_scale must be > 0".into(),
            ));
        }
        if self.delay_slots == 0 {
            return Err(Error::Config(
                "faults.delay_slots must be >= 1".into(),
            ));
        }
        for (key, v) in [
            ("faults.retransmit_ms", self.retransmit_ms),
            ("faults.probe_ms", self.probe_ms),
            ("faults.suspect_ms", self.suspect_ms),
            ("faults.dead_ms", self.dead_ms),
        ] {
            if v == 0 {
                return Err(Error::Config(format!("{key} must be >= 1")));
            }
        }
        if self.suspect_ms > self.dead_ms {
            return Err(Error::Config(format!(
                "faults.suspect_ms ({}) must be <= faults.dead_ms ({})",
                self.suspect_ms, self.dead_ms
            )));
        }
        if !(self.p99_budget > 0.0) {
            return Err(Error::Config(
                "faults.p99_budget must be > 0".into(),
            ));
        }
        Ok(())
    }
}

/// Where `ns-lbp compile` puts things (`[compile]` section); the CLI
/// `--out-dir` / `--cache-dir` options override per invocation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CompileDirs {
    /// Finished `<name>-<version>.nslbpc` artifacts.
    pub out_dir: String,
    /// Per-stage compile-cache entries (safe to delete any time).
    pub cache_dir: String,
}

impl Default for CompileDirs {
    fn default() -> Self {
        Self {
            out_dir: "artifacts/models".into(),
            cache_dir: "artifacts/compile-cache".into(),
        }
    }
}

impl Default for SystemConfig {
    fn default() -> Self {
        Self {
            cache: crate::sram::CacheGeometry::default(),
            circuit: crate::circuit::CircuitParams::default(),
            sensor: crate::sensor::SensorConfig::default(),
            serve: ServeConfig::default(),
            fleet: FleetConfig::default(),
            faults: FaultsConfig::default(),
            engine: EngineSelection::default(),
            hw: HwSelection::default(),
            obs: crate::obs::ObsConfig::default(),
            compile: CompileDirs::default(),
            workers: 0,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl SystemConfig {
    /// Build from a parsed file; unknown keys are rejected so typos fail
    /// loudly rather than silently falling back to defaults.
    pub fn from_file(file: &ConfigFile) -> Result<Self> {
        const KNOWN: &[&str] = &[
            "cache.banks", "cache.mats_per_bank", "cache.subarrays_per_mat",
            "cache.rows", "cache.cols",
            "cache.pixel_rows", "cache.pivot_rows", "cache.reserved_rows",
            "cache.weight_rows", "cache.input_rows",
            "circuit.vdd", "circuit.rwl_voltage", "circuit.v_r1",
            "circuit.v_r2", "circuit.v_r3", "circuit.freq_ghz",
            "circuit.sigma_process", "circuit.sigma_mismatch",
            "sensor.rows", "sensor.cols", "sensor.channels",
            "sensor.adc_bits", "sensor.skip_lsbs", "sensor.fps",
            "serve.shards", "serve.queue_depth", "serve.max_batch",
            "serve.batch_deadline_us", "serve.model_cache",
            "serve.best_effort.queue_depth", "serve.best_effort.max_batch",
            "serve.best_effort.deadline_us", "serve.best_effort.drop_oldest",
            "serve.standard.queue_depth", "serve.standard.max_batch",
            "serve.standard.deadline_us", "serve.standard.drop_oldest",
            "serve.billed.queue_depth", "serve.billed.max_batch",
            "serve.billed.deadline_us", "serve.billed.drop_oldest",
            "serve.async.enabled", "serve.async.workers",
            "serve.async.min_shards", "serve.async.max_shards",
            "serve.async.quantum", "serve.async.scale_up_depth",
            "serve.async.scale_down_idle", "serve.async.scale_interval_us",
            "fleet.nodes",
            "fleet.capacity.best_effort", "fleet.capacity.standard",
            "fleet.capacity.billed",
            "fleet.drill.kill_node", "fleet.drill.kill_after",
            "fleet.drill.p99_budget",
            "faults.enabled", "faults.seed",
            "faults.drop_prob", "faults.dup_prob", "faults.delay_prob",
            "faults.delay_slots",
            "faults.flap_node", "faults.flap_after", "faults.flap_len",
            "faults.stall_prob", "faults.stall_us", "faults.panic_prob",
            "faults.artifact_corrupt_prob", "faults.bitflip_sigma_scale",
            "faults.retransmit_ms", "faults.probe_ms", "faults.suspect_ms",
            "faults.dead_ms", "faults.degrade_after", "faults.p99_budget",
            "engine.backend", "engine.cross_check", "engine.pjrt_artifact",
            "engine.routing.best_effort", "engine.routing.standard",
            "engine.routing.billed",
            "obs.enabled", "obs.ring_capacity", "obs.sample_period_us",
            "obs.jsonl_path",
            "compile.out_dir", "compile.cache_dir",
            "runtime.workers", "runtime.artifacts_dir",
        ];
        // `[hw]` keys: the profile selector plus flat field overrides
        // (the legal field set lives in hw, next to the sectioned
        // profile-file parser, so the two surfaces cannot drift)
        for key in file.keys() {
            let ok = KNOWN.contains(&key)
                || key.strip_prefix("hw.").is_some_and(|field| {
                    field == "profile" || HwProfile::is_override_field(field)
                });
            if !ok {
                return Err(Error::Config(format!("unknown config key {key:?}")));
            }
        }

        let d = Self::default();
        let cache = crate::sram::CacheGeometry {
            banks: file.get_usize("cache.banks", d.cache.banks)?,
            mats_per_bank: file
                .get_usize("cache.mats_per_bank", d.cache.mats_per_bank)?,
            subarrays_per_mat: file
                .get_usize("cache.subarrays_per_mat", d.cache.subarrays_per_mat)?,
            rows: file.get_usize("cache.rows", d.cache.rows)?,
            cols: file.get_usize("cache.cols", d.cache.cols)?,
            region: crate::sram::RegionLayout {
                pixel_rows: file
                    .get_usize("cache.pixel_rows", d.cache.region.pixel_rows)?,
                pivot_rows: file
                    .get_usize("cache.pivot_rows", d.cache.region.pivot_rows)?,
                reserved_rows: file
                    .get_usize("cache.reserved_rows", d.cache.region.reserved_rows)?,
                weight_rows: file
                    .get_usize("cache.weight_rows", d.cache.region.weight_rows)?,
                input_rows: file
                    .get_usize("cache.input_rows", d.cache.region.input_rows)?,
            },
        };
        cache.validate()?;

        let circuit = crate::circuit::CircuitParams {
            vdd: file.get_f64("circuit.vdd", d.circuit.vdd)?,
            rwl_voltage: file.get_f64("circuit.rwl_voltage", d.circuit.rwl_voltage)?,
            v_r1: file.get_f64("circuit.v_r1", d.circuit.v_r1)?,
            v_r2: file.get_f64("circuit.v_r2", d.circuit.v_r2)?,
            v_r3: file.get_f64("circuit.v_r3", d.circuit.v_r3)?,
            freq_ghz: file.get_f64("circuit.freq_ghz", d.circuit.freq_ghz)?,
            sigma_process: file
                .get_f64("circuit.sigma_process", d.circuit.sigma_process)?,
            sigma_mismatch: file
                .get_f64("circuit.sigma_mismatch", d.circuit.sigma_mismatch)?,
        };
        circuit.validate()?;

        let sensor = crate::sensor::SensorConfig {
            rows: file.get_usize("sensor.rows", d.sensor.rows)?,
            cols: file.get_usize("sensor.cols", d.sensor.cols)?,
            channels: file.get_usize("sensor.channels", d.sensor.channels)?,
            adc_bits: file.get_usize("sensor.adc_bits", d.sensor.adc_bits)?,
            skip_lsbs: file.get_usize("sensor.skip_lsbs", d.sensor.skip_lsbs)?,
            fps: file.get_f64("sensor.fps", d.sensor.fps)?,
        };
        sensor.validate()?;

        let mut classes = [ClassPolicy::default(); QosClass::COUNT];
        for class in QosClass::ALL {
            let p = &mut classes[class.index()];
            let key = |field: &str| format!("serve.{class}.{field}");
            let depth_key = key("queue_depth");
            if file.contains(&depth_key) {
                p.queue_depth = Some(file.get_usize(&depth_key, 0)?);
            }
            let batch_key = key("max_batch");
            if file.contains(&batch_key) {
                p.max_batch = Some(file.get_usize(&batch_key, 0)?);
            }
            let deadline_key = key("deadline_us");
            if file.contains(&deadline_key) {
                p.deadline_us =
                    Some(file.get_usize(&deadline_key, 0)? as u64);
            }
            let drop_key = key("drop_oldest");
            if file.contains(&drop_key) {
                p.drop_oldest = Some(file.get_bool(&drop_key, false)?);
            }
        }
        let da = d.serve.async_plane;
        let async_plane = AsyncServeConfig {
            enabled: file.get_bool("serve.async.enabled", da.enabled)?,
            workers: file.get_usize("serve.async.workers", da.workers)?,
            min_shards: file
                .get_usize("serve.async.min_shards", da.min_shards)?,
            max_shards: file
                .get_usize("serve.async.max_shards", da.max_shards)?,
            quantum: file
                .get_usize("serve.async.quantum", da.quantum as usize)?
                as u32,
            scale_up_depth: file
                .get_usize("serve.async.scale_up_depth", da.scale_up_depth)?,
            scale_down_idle: file
                .get_usize("serve.async.scale_down_idle",
                           da.scale_down_idle as usize)? as u32,
            scale_interval_us: file
                .get_usize("serve.async.scale_interval_us",
                           da.scale_interval_us as usize)? as u64,
        };
        let serve = ServeConfig {
            shards: file.get_usize("serve.shards", d.serve.shards)?,
            queue_depth: file
                .get_usize("serve.queue_depth", d.serve.queue_depth)?,
            max_batch: file.get_usize("serve.max_batch", d.serve.max_batch)?,
            batch_deadline_us: file
                .get_usize("serve.batch_deadline_us",
                           d.serve.batch_deadline_us as usize)? as u64,
            model_cache: file
                .get_usize("serve.model_cache", d.serve.model_cache)?,
            classes,
            async_plane,
        };
        serve.validate()?;

        let mut capacity = d.fleet.capacity;
        for class in QosClass::ALL {
            let key = format!("fleet.capacity.{class}");
            if file.contains(&key) {
                capacity[class.index()] = file.get_usize(&key, 0)?;
            }
        }
        let fleet = FleetConfig {
            nodes: file.get_usize("fleet.nodes", d.fleet.nodes)?,
            capacity,
            drill: DrillKnobs {
                kill_node: file
                    .get_usize("fleet.drill.kill_node", d.fleet.drill.kill_node)?,
                kill_after: file
                    .get_usize("fleet.drill.kill_after",
                               d.fleet.drill.kill_after)?,
                p99_budget: file
                    .get_f64("fleet.drill.p99_budget",
                             d.fleet.drill.p99_budget)?,
            },
        };
        fleet.validate()?;

        let df = d.faults;
        let faults = FaultsConfig {
            enabled: file.get_bool("faults.enabled", df.enabled)?,
            seed: file.get_usize("faults.seed", df.seed as usize)? as u64,
            drop_prob: file.get_f64("faults.drop_prob", df.drop_prob)?,
            dup_prob: file.get_f64("faults.dup_prob", df.dup_prob)?,
            delay_prob: file.get_f64("faults.delay_prob", df.delay_prob)?,
            delay_slots: file
                .get_usize("faults.delay_slots", df.delay_slots)?,
            flap_node: file.get_usize("faults.flap_node", df.flap_node)?,
            flap_after: file.get_usize("faults.flap_after", df.flap_after)?,
            flap_len: file.get_usize("faults.flap_len", df.flap_len)?,
            stall_prob: file.get_f64("faults.stall_prob", df.stall_prob)?,
            stall_us: file
                .get_usize("faults.stall_us", df.stall_us as usize)?
                as u64,
            panic_prob: file.get_f64("faults.panic_prob", df.panic_prob)?,
            artifact_corrupt_prob: file.get_f64(
                "faults.artifact_corrupt_prob", df.artifact_corrupt_prob)?,
            bitflip_sigma_scale: file.get_f64(
                "faults.bitflip_sigma_scale", df.bitflip_sigma_scale)?,
            retransmit_ms: file
                .get_usize("faults.retransmit_ms", df.retransmit_ms as usize)?
                as u64,
            probe_ms: file
                .get_usize("faults.probe_ms", df.probe_ms as usize)?
                as u64,
            suspect_ms: file
                .get_usize("faults.suspect_ms", df.suspect_ms as usize)?
                as u64,
            dead_ms: file
                .get_usize("faults.dead_ms", df.dead_ms as usize)?
                as u64,
            degrade_after: file
                .get_usize("faults.degrade_after", df.degrade_after as usize)?
                as u64,
            p99_budget: file.get_f64("faults.p99_budget", df.p99_budget)?,
        };
        faults.validate()?;

        let mut routing = RoutingPolicy::default();
        for class in QosClass::ALL {
            let key = format!("engine.routing.{class}");
            if let Some(kind) = BackendKind::parse_optional(
                &file.get_str(&key, "none")?,
            )? {
                routing.set(class, kind);
            }
        }
        let engine = EngineSelection {
            backend: file
                .get_str("engine.backend", d.engine.backend.as_str())?
                .parse()?,
            cross_check: BackendKind::parse_optional(&file.get_str(
                "engine.cross_check",
                d.engine.cross_check.map_or("none", |k| k.as_str()),
            )?)?,
            pjrt_artifact: file
                .get_str("engine.pjrt_artifact", &d.engine.pjrt_artifact)?,
            routing,
        };

        let obs = crate::obs::ObsConfig {
            enabled: file.get_bool("obs.enabled", d.obs.enabled)?,
            ring_capacity: file
                .get_usize("obs.ring_capacity", d.obs.ring_capacity)?,
            sample_period_us: file
                .get_usize("obs.sample_period_us",
                           d.obs.sample_period_us as usize)?
                as u64,
            jsonl_path: file.get_str("obs.jsonl_path", &d.obs.jsonl_path)?,
        };

        let mut hw = HwSelection::default();
        if file.contains("hw.profile") {
            hw.profile = HwProfile::resolve(&file.get_str("hw.profile", "")?)?;
        }
        hw.profile.apply_overrides(file, "hw.")?;
        hw.clock_explicit = file.contains("hw.freq_ghz");
        hw.profile.validate()?;

        let compile = CompileDirs {
            out_dir: file.get_str("compile.out_dir", &d.compile.out_dir)?,
            cache_dir: file
                .get_str("compile.cache_dir", &d.compile.cache_dir)?,
        };

        Ok(Self {
            cache,
            circuit,
            sensor,
            serve,
            fleet,
            faults,
            engine,
            hw,
            obs,
            compile,
            workers: file.get_usize("runtime.workers", d.workers)?,
            artifacts_dir: file.get_str("runtime.artifacts_dir", &d.artifacts_dir)?,
        })
    }

    /// The hardware profile backends price telemetry with.  For the
    /// default `ns_lbp_65nm` profile *at its stock clock* the
    /// `[circuit]` frequency wins (so VDD/frequency sweeps keep working
    /// as before the `hw` subsystem); an explicit hw-side clock — an
    /// `hw.freq_ghz` override, or a profile carrying its own frequency —
    /// always wins over `[circuit]`.
    pub fn hw_profile(&self) -> HwProfile {
        let mut p = self.hw.profile.clone();
        let stock = crate::energy::EnergyParams::default().freq_ghz;
        if !self.hw.clock_explicit
            && p.name == "ns_lbp_65nm"
            && p.energy.freq_ghz == stock
        {
            p.energy.freq_ghz = self.circuit.freq_ghz;
        }
        p
    }

    /// Load defaults, then an optional file, then CLI overrides.
    pub fn load(path: Option<&str>, overrides: &[String]) -> Result<Self> {
        let mut file = match path {
            Some(p) => ConfigFile::load(p)?,
            None => ConfigFile::default(),
        };
        for o in overrides {
            file.set_override(o)?;
        }
        Self::from_file(&file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
        # NS-LBP sample
        [cache]
        banks = 80
        rows = 256          # per sub-array
        [circuit]
        vdd = 1.1
        freq_ghz = 1.25
        [sensor]
        adc_bits = 8
        [runtime]
        artifacts_dir = "artifacts"
    "#;

    #[test]
    fn parses_sections_and_types() {
        let f = ConfigFile::parse(SAMPLE).unwrap();
        assert_eq!(f.get_i64("cache.banks", 0).unwrap(), 80);
        assert_eq!(f.get_f64("circuit.vdd", 0.0).unwrap(), 1.1);
        assert_eq!(f.get_str("runtime.artifacts_dir", "").unwrap(), "artifacts");
        assert_eq!(f.get_i64("cache.missing", 7).unwrap(), 7);
    }

    #[test]
    fn rejects_type_mismatch() {
        let f = ConfigFile::parse("x = \"hello\"").unwrap();
        assert!(f.get_i64("x", 0).is_err());
    }

    #[test]
    fn parses_arrays_bools_underscores() {
        let f = ConfigFile::parse("a = [1, 2, 3]\nb = true\nc = 1_000_000").unwrap();
        assert!(matches!(f.get("a"), Some(Value::Array(v)) if v.len() == 3));
        assert!(f.get_bool("b", false).unwrap());
        assert_eq!(f.get_i64("c", 0).unwrap(), 1_000_000);
    }

    #[test]
    fn comment_inside_string_preserved() {
        let f = ConfigFile::parse("k = \"a#b\"").unwrap();
        assert_eq!(f.get_str("k", "").unwrap(), "a#b");
    }

    #[test]
    fn error_carries_line_number() {
        let err = ConfigFile::parse("ok = 1\nnot a kv line").unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
    }

    #[test]
    fn system_config_defaults_match_paper() {
        let sc = SystemConfig::default();
        assert_eq!(sc.cache.banks, 80);
        assert_eq!(sc.cache.rows, 256);
        assert_eq!(sc.cache.cols, 256);
        assert!((sc.circuit.freq_ghz - 1.25).abs() < 1e-9);
        assert!((sc.circuit.vdd - 1.1).abs() < 1e-9);
    }

    #[test]
    fn system_config_rejects_unknown_keys() {
        let f = ConfigFile::parse("[cache]\nbnaks = 80").unwrap();
        assert!(SystemConfig::from_file(&f).is_err());
    }

    #[test]
    fn overrides_apply() {
        let mut f = ConfigFile::default();
        f.set_override("cache.banks=40").unwrap();
        let sc = SystemConfig::from_file(&f).unwrap();
        assert_eq!(sc.cache.banks, 40);
    }

    #[test]
    fn engine_selection_parses_and_rejects_unknown() {
        let f = ConfigFile::parse(
            "[engine]\nbackend = \"functional\"\ncross_check = \"architectural\"",
        )
        .unwrap();
        let sc = SystemConfig::from_file(&f).unwrap();
        assert_eq!(sc.engine.backend, BackendKind::Functional);
        assert_eq!(sc.engine.cross_check, Some(BackendKind::Architectural));

        let off = ConfigFile::parse(
            "[engine]\ncross_check = \"none\"\npjrt_artifact = \"aplbp_svhn\"",
        )
        .unwrap();
        let sc = SystemConfig::from_file(&off).unwrap();
        assert_eq!(sc.engine.backend, BackendKind::Architectural);
        assert_eq!(sc.engine.cross_check, None);
        assert_eq!(sc.engine.pjrt_artifact, "aplbp_svhn");

        let bad = ConfigFile::parse("[engine]\nbackend = \"warp\"").unwrap();
        assert!(SystemConfig::from_file(&bad).is_err());
    }

    #[test]
    fn routing_section_parses_per_class_backends() {
        let f = ConfigFile::parse(
            "[engine.routing]\nbest_effort = \"functional\"\n\
             billed = \"architectural\"",
        )
        .unwrap();
        let sc = SystemConfig::from_file(&f).unwrap();
        assert_eq!(sc.engine.routing.route(QosClass::BestEffort),
                   Some(BackendKind::Functional));
        assert_eq!(sc.engine.routing.route(QosClass::Standard), None);
        assert_eq!(sc.engine.routing.route(QosClass::Billed),
                   Some(BackendKind::Architectural));

        let off = ConfigFile::parse("[engine.routing]\nbilled = \"none\"")
            .unwrap();
        let sc = SystemConfig::from_file(&off).unwrap();
        assert!(sc.engine.routing.is_empty());

        let bad = ConfigFile::parse("[engine.routing]\ngold = \"functional\"")
            .unwrap();
        assert!(SystemConfig::from_file(&bad).is_err());
    }

    #[test]
    fn per_class_serve_knobs_resolve_with_fallbacks() {
        let f = ConfigFile::parse(
            "[serve]\nqueue_depth = 64\nmax_batch = 8\n\
             batch_deadline_us = 1000\n\
             [serve.best_effort]\nqueue_depth = 4\ndeadline_us = 100\n\
             [serve.billed]\nmax_batch = 32\ndrop_oldest = true",
        )
        .unwrap();
        let sc = SystemConfig::from_file(&f).unwrap();
        let be = sc.serve.class_knobs(QosClass::BestEffort);
        assert_eq!(be.queue_depth, 4);
        assert_eq!(be.max_batch, 8); // falls back to [serve]
        assert_eq!(be.deadline_us, 100);
        assert!(be.drop_oldest); // best-effort default
        let std_k = sc.serve.class_knobs(QosClass::Standard);
        assert_eq!(std_k.queue_depth, 64);
        assert!(!std_k.drop_oldest);
        let billed = sc.serve.class_knobs(QosClass::Billed);
        assert_eq!(billed.max_batch, 32);
        assert_eq!(billed.deadline_us, 1000);
        assert!(billed.drop_oldest); // explicit override

        let bad =
            ConfigFile::parse("[serve.standard]\nmax_batch = 0").unwrap();
        assert!(SystemConfig::from_file(&bad).is_err());
    }

    #[test]
    fn hw_section_selects_profiles_and_applies_overrides() {
        // default: the paper's point
        let sc = SystemConfig::default();
        assert_eq!(sc.hw.profile.name, "ns_lbp_65nm");
        assert_eq!(sc.hw_profile().name, "ns_lbp_65nm");

        // select a builtin by name
        let f = ConfigFile::parse("[hw]\nprofile = \"sram38_28nm\"").unwrap();
        let sc = SystemConfig::from_file(&f).unwrap();
        assert_eq!(sc.hw.profile.name, "sram38_28nm");
        assert!((sc.hw.profile.energy_scale - 1.55).abs() < 1e-12);
        // a non-default profile carries its own clock (circuit freq does
        // not clobber it)
        assert!((sc.hw_profile().energy.freq_ghz - 0.475).abs() < 1e-12);

        // flat field overrides
        let f = ConfigFile::parse(
            "[hw]\nfreq_ghz = 2.0\ncompute_op_pj = 3.5\nsa_overhead = 4.0\n\
             energy_scale = 1.2\nmac_lanes = 128\ncycles.copy = 3",
        )
        .unwrap();
        let sc = SystemConfig::from_file(&f).unwrap();
        assert!((sc.hw.profile.energy.freq_ghz - 2.0).abs() < 1e-12);
        assert!((sc.hw.profile.energy.compute_op_pj - 3.5).abs() < 1e-12);
        assert!((sc.hw.profile.area.sa_overhead - 4.0).abs() < 1e-12);
        assert!((sc.hw.profile.energy_scale - 1.2).abs() < 1e-12);
        assert_eq!(sc.hw.profile.mac_lanes, 128);
        assert_eq!(sc.hw.profile.cycles.of(crate::isa::Opcode::Copy), 3);
        // an explicit hw-side clock survives hw_profile(): [circuit]'s
        // default 1.25 GHz must NOT clobber the user's 2.0 GHz
        assert!((sc.hw_profile().energy.freq_ghz - 2.0).abs() < 1e-12);

        // the default profile still tracks [circuit] freq_ghz (VDD sweeps)
        let f = ConfigFile::parse("[circuit]\nfreq_ghz = 0.9").unwrap();
        let sc = SystemConfig::from_file(&f).unwrap();
        assert!((sc.hw_profile().energy.freq_ghz - 0.9).abs() < 1e-12);

        // ... but an explicit hw.freq_ghz wins even at the stock value
        let f = ConfigFile::parse(
            "[circuit]\nfreq_ghz = 0.9\n[hw]\nfreq_ghz = 1.25",
        )
        .unwrap();
        let sc = SystemConfig::from_file(&f).unwrap();
        assert!(sc.hw.clock_explicit);
        assert!((sc.hw_profile().energy.freq_ghz - 1.25).abs() < 1e-12);

        // unknown profiles and unknown fields fail loudly
        let bad = ConfigFile::parse("[hw]\nprofile = \"tpu_v9\"").unwrap();
        assert!(SystemConfig::from_file(&bad).is_err());
        let bad = ConfigFile::parse("[hw]\nwarp_pj = 1.0").unwrap();
        assert!(SystemConfig::from_file(&bad).is_err());
        let bad = ConfigFile::parse("[hw]\nfreq_ghz = 0.0").unwrap();
        assert!(SystemConfig::from_file(&bad).is_err());
    }

    #[test]
    fn obs_section_parses_with_defaults() {
        let sc = SystemConfig::default();
        assert!(!sc.obs.enabled);
        assert_eq!(sc.obs.ring_capacity, 65536);

        let f = ConfigFile::parse(
            "[obs]\nenabled = true\nring_capacity = 1024\n\
             sample_period_us = 5000\njsonl_path = \"out/t.jsonl\"",
        )
        .unwrap();
        let sc = SystemConfig::from_file(&f).unwrap();
        assert!(sc.obs.enabled);
        assert_eq!(sc.obs.ring_capacity, 1024);
        assert_eq!(sc.obs.sample_period_us, 5000);
        assert_eq!(sc.obs.jsonl_path, "out/t.jsonl");
        assert_eq!(sc.obs.chrome_path(), "out/t.trace.json");

        let bad = ConfigFile::parse("[obs]\nring_cap = 9").unwrap();
        assert!(SystemConfig::from_file(&bad).is_err());
    }

    #[test]
    fn serve_knobs_parse_and_validate() {
        let f = ConfigFile::parse(
            "[serve]\nshards = 2\nqueue_depth = 64\nmax_batch = 8\n\
             batch_deadline_us = 500",
        )
        .unwrap();
        let sc = SystemConfig::from_file(&f).unwrap();
        assert_eq!(sc.serve.shards, 2);
        assert_eq!(sc.serve.queue_depth, 64);
        assert_eq!(sc.serve.max_batch, 8);
        assert_eq!(sc.serve.batch_deadline().as_micros(), 500);

        let bad = ConfigFile::parse("[serve]\nshards = 0").unwrap();
        assert!(SystemConfig::from_file(&bad).is_err());
    }

    #[test]
    fn async_serve_knobs_parse_and_validate() {
        let f = ConfigFile::parse(
            "[serve]\nshards = 4\n\n[serve.async]\nenabled = true\n\
             workers = 3\nmin_shards = 2\nmax_shards = 6\nquantum = 2\n\
             scale_up_depth = 4\nscale_down_idle = 16\n\
             scale_interval_us = 250",
        )
        .unwrap();
        let sc = SystemConfig::from_file(&f).unwrap();
        let a = sc.serve.async_plane;
        assert!(a.enabled);
        assert_eq!(a.workers, 3);
        assert_eq!(a.min_shards, 2);
        assert_eq!(a.max_shards, 6);
        assert_eq!(a.max_shards_or(sc.serve.shards), 6);
        assert_eq!(a.quantum, 2);
        assert_eq!(a.scale_up_depth, 4);
        assert_eq!(a.scale_down_idle, 16);
        assert_eq!(a.scale_interval().as_micros(), 250);

        // defaults: disabled, ceiling follows serve.shards
        let plain = ConfigFile::parse("[serve]\nshards = 3").unwrap();
        let sc = SystemConfig::from_file(&plain).unwrap();
        assert!(!sc.serve.async_plane.enabled);
        assert_eq!(sc.serve.async_plane.max_shards_or(sc.serve.shards), 3);

        // inverted range and zero knobs fail loudly
        let bad = ConfigFile::parse(
            "[serve]\nshards = 2\n\n[serve.async]\nmin_shards = 4",
        )
        .unwrap();
        assert!(SystemConfig::from_file(&bad).is_err());
        let bad =
            ConfigFile::parse("[serve.async]\nquantum = 0").unwrap();
        assert!(SystemConfig::from_file(&bad).is_err());
        let bad =
            ConfigFile::parse("[serve.async]\nquantun = 1").unwrap();
        assert!(SystemConfig::from_file(&bad).is_err());
    }

    #[test]
    fn fleet_knobs_parse_and_validate() {
        let f = ConfigFile::parse(
            "[fleet]\nnodes = 5\n\n[fleet.capacity]\nbilled = 8\n\n\
             [fleet.drill]\nkill_node = 2\nkill_after = 16\n\
             p99_budget = 10.0",
        )
        .unwrap();
        let sc = SystemConfig::from_file(&f).unwrap();
        assert_eq!(sc.fleet.nodes, 5);
        assert_eq!(sc.fleet.capacity[QosClass::Billed.index()], 8);
        // Unset classes keep the default capacity.
        assert_eq!(sc.fleet.capacity[QosClass::Standard.index()],
                   FleetConfig::default().capacity[1]);
        assert_eq!(sc.fleet.drill.kill_node, 2);
        assert_eq!(sc.fleet.drill.kill_after, 16);
        assert_eq!(sc.fleet.drill.p99_budget, 10.0);

        let bad = ConfigFile::parse("[fleet]\nnodes = 0").unwrap();
        assert!(SystemConfig::from_file(&bad).is_err());
        let bad = ConfigFile::parse("[fleet]\nnodes = 2\n\n[fleet.drill]\n\
                                     kill_node = 2")
            .unwrap();
        assert!(SystemConfig::from_file(&bad).is_err());
        let bad = ConfigFile::parse("[fleet]\nnods = 3").unwrap();
        assert!(SystemConfig::from_file(&bad).is_err());
    }

    #[test]
    fn faults_knobs_parse_and_validate() {
        // defaults: disabled, nominal sigma, sane recovery windows
        let sc = SystemConfig::default();
        assert!(!sc.faults.enabled);
        assert_eq!(sc.faults.seed, 42);
        assert_eq!(sc.faults.bitflip_sigma_scale, 1.0);

        let f = ConfigFile::parse(
            "[faults]\nenabled = true\nseed = 7\ndrop_prob = 0.05\n\
             dup_prob = 0.02\ndelay_prob = 0.1\ndelay_slots = 3\n\
             flap_node = 1\nflap_after = 10\nflap_len = 20\n\
             stall_prob = 0.5\nstall_us = 800\npanic_prob = 0.01\n\
             artifact_corrupt_prob = 0.25\nbitflip_sigma_scale = 2.5\n\
             retransmit_ms = 100\nprobe_ms = 10\nsuspect_ms = 40\n\
             dead_ms = 120\ndegrade_after = 2\np99_budget = 900.0",
        )
        .unwrap();
        let sc = SystemConfig::from_file(&f).unwrap();
        assert!(sc.faults.enabled);
        assert_eq!(sc.faults.seed, 7);
        assert_eq!(sc.faults.drop_prob, 0.05);
        assert_eq!(sc.faults.delay_slots, 3);
        assert_eq!((sc.faults.flap_node, sc.faults.flap_after,
                    sc.faults.flap_len), (1, 10, 20));
        assert_eq!(sc.faults.stall_us, 800);
        assert_eq!(sc.faults.bitflip_sigma_scale, 2.5);
        assert_eq!(sc.faults.retransmit_ms, 100);
        assert_eq!((sc.faults.suspect_ms, sc.faults.dead_ms), (40, 120));
        assert_eq!(sc.faults.degrade_after, 2);
        assert_eq!(sc.faults.p99_budget, 900.0);

        // out-of-range probabilities, inverted health windows, and typos
        // fail loudly
        let bad = ConfigFile::parse("[faults]\ndrop_prob = 1.5").unwrap();
        assert!(SystemConfig::from_file(&bad).is_err());
        let bad = ConfigFile::parse(
            "[faults]\nsuspect_ms = 500\ndead_ms = 100").unwrap();
        assert!(SystemConfig::from_file(&bad).is_err());
        let bad = ConfigFile::parse("[faults]\ndorp_prob = 0.1").unwrap();
        assert!(SystemConfig::from_file(&bad).is_err());
        let bad =
            ConfigFile::parse("[faults]\nbitflip_sigma_scale = 0.0").unwrap();
        assert!(SystemConfig::from_file(&bad).is_err());
    }
}
