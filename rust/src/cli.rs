//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, repeated
//! options, positional arguments, and generated `--help` text.  Used by
//! `rust/src/main.rs` and the examples.

use crate::error::{Error, Result};

/// Option specification.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    /// None for boolean flags; Some(placeholder) for valued options.
    pub value: Option<&'static str>,
    pub help: &'static str,
    pub repeated: bool,
}

/// A parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Parsed {
    pub subcommand: Option<String>,
    flags: Vec<String>,
    options: Vec<(String, String)>,
    pub positionals: Vec<String>,
}

impl Parsed {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options
            .iter()
            .rev()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    pub fn opt_all(&self, name: &str) -> Vec<String> {
        self.options
            .iter()
            .filter(|(k, _)| k == name)
            .map(|(_, v)| v.clone())
            .collect()
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Usage(format!("--{name}: cannot parse {v:?}"))
            }),
        }
    }
}

/// Command definition: subcommands + options.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub subcommands: Vec<(&'static str, &'static str)>,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, subcommands: Vec::new(), opts: Vec::new() }
    }

    pub fn subcommand(mut self, name: &'static str, about: &'static str) -> Self {
        self.subcommands.push((name, about));
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, value: None, help, repeated: false });
        self
    }

    pub fn opt(mut self, name: &'static str, placeholder: &'static str,
               help: &'static str) -> Self {
        self.opts.push(OptSpec { name, value: Some(placeholder), help,
                                 repeated: false });
        self
    }

    pub fn opt_repeated(mut self, name: &'static str, placeholder: &'static str,
                        help: &'static str) -> Self {
        self.opts.push(OptSpec { name, value: Some(placeholder), help,
                                 repeated: true });
        self
    }

    fn spec(&self, name: &str) -> Option<&OptSpec> {
        self.opts.iter().find(|o| o.name == name)
    }

    /// Render `--help`.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n    {} ", self.name, self.about,
                            self.name);
        if !self.subcommands.is_empty() {
            s.push_str("<SUBCOMMAND> ");
        }
        s.push_str("[OPTIONS]\n");
        if !self.subcommands.is_empty() {
            s.push_str("\nSUBCOMMANDS:\n");
            for (n, about) in &self.subcommands {
                s.push_str(&format!("    {n:<14} {about}\n"));
            }
        }
        s.push_str("\nOPTIONS:\n");
        for o in &self.opts {
            let lhs = match o.value {
                Some(ph) => format!("--{} <{}>", o.name, ph),
                None => format!("--{}", o.name),
            };
            s.push_str(&format!("    {lhs:<26} {}\n", o.help));
        }
        s.push_str("    --help                     print this help\n");
        s
    }

    /// Parse argv (without the program name).
    pub fn parse(&self, args: &[String]) -> Result<Parsed> {
        let mut parsed = Parsed::default();
        let mut it = args.iter().peekable();

        if !self.subcommands.is_empty() {
            match it.peek() {
                Some(first) if !first.starts_with('-') => {
                    let name = it.next().unwrap();
                    if !self.subcommands.iter().any(|(n, _)| n == name) {
                        return Err(Error::Usage(format!(
                            "unknown subcommand {name:?}; try --help"
                        )));
                    }
                    parsed.subcommand = Some(name.clone());
                }
                _ => {}
            }
        }

        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(Error::Usage(self.help()));
            }
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self.spec(name).ok_or_else(|| {
                    Error::Usage(format!("unknown option --{name}; try --help"))
                })?;
                match (spec.value, inline_val) {
                    (None, None) => parsed.flags.push(name.to_string()),
                    (None, Some(_)) => {
                        return Err(Error::Usage(format!(
                            "--{name} is a flag and takes no value"
                        )))
                    }
                    (Some(_), Some(v)) => {
                        if !spec.repeated && parsed.opt(name).is_some() {
                            return Err(Error::Usage(format!(
                                "--{name} given more than once"
                            )));
                        }
                        parsed.options.push((name.to_string(), v));
                    }
                    (Some(_), None) => {
                        let v = it.next().ok_or_else(|| {
                            Error::Usage(format!("--{name} expects a value"))
                        })?;
                        if !spec.repeated && parsed.opt(name).is_some() {
                            return Err(Error::Usage(format!(
                                "--{name} given more than once"
                            )));
                        }
                        parsed.options.push((name.to_string(), v.clone()));
                    }
                }
            } else {
                parsed.positionals.push(arg.clone());
            }
        }
        Ok(parsed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("ns-lbp", "test")
            .subcommand("run", "run the pipeline")
            .subcommand("bench", "benchmarks")
            .flag("verbose", "chatty")
            .opt("config", "FILE", "config path")
            .opt_repeated("set", "K=V", "override")
    }

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_options() {
        let p = cmd()
            .parse(&args(&["run", "--verbose", "--config", "x.toml",
                           "--set", "a=1", "--set=b=2", "pos1"]))
            .unwrap();
        assert_eq!(p.subcommand.as_deref(), Some("run"));
        assert!(p.flag("verbose"));
        assert!(!p.flag("quiet"));
        assert_eq!(p.opt("config"), Some("x.toml"));
        assert_eq!(p.opt_all("set"), vec!["a=1".to_string(), "b=2".to_string()]);
        assert_eq!(p.positionals, vec!["pos1".to_string()]);
    }

    #[test]
    fn rejects_unknown_and_misused() {
        assert!(cmd().parse(&args(&["frobnicate"])).is_err());
        assert!(cmd().parse(&args(&["--nope"])).is_err());
        assert!(cmd().parse(&args(&["--config"])).is_err()); // missing value
        assert!(cmd().parse(&args(&["--verbose=1"])).is_err()); // flag w/ value
        assert!(cmd()
            .parse(&args(&["--config", "a", "--config", "b"]))
            .is_err()); // non-repeated repeated
    }

    #[test]
    fn opt_parse_with_default() {
        let p = cmd().parse(&args(&["--config", "x"])).unwrap();
        let n: usize = p.opt_parse("missing-not-declared", 7).unwrap();
        assert_eq!(n, 7);
    }

    #[test]
    fn help_lists_everything() {
        let h = cmd().help();
        for needle in ["run", "bench", "--verbose", "--config", "--set"] {
            assert!(h.contains(needle), "missing {needle} in help");
        }
    }
}
