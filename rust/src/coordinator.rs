//! The near-sensor coordinator: sensor → engine → classification, with
//! worker-thread fan-out and run-level aggregation.
//!
//! Since the engine redesign the coordinator no longer owns any inference
//! logic; it builds one [`crate::engine::Engine`] per worker thread from
//! its configuration (`system.engine.backend` selects the execution path,
//! `system.engine.cross_check` an optional reference backend) and merges
//! the per-frame [`FrameReport`]s into a [`RunSummary`].  Frames are
//! independent, so the run loop fans out over worker threads
//! (std::thread — tokio is unavailable offline), each with its own
//! engine (and therefore its own scratch sub-array); the modeled
//! accelerator time still assumes the paper's geometry (batches spread
//! across the cache's sub-arrays).
//!
//! `ArchSim`, `ShardSlice`, and the configuration struct now live in
//! [`crate::engine`]; this module re-exports them under their historical
//! names so existing call sites keep working.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

use crate::dpu::DpuStats;
use crate::energy::EnergyBreakdown;
use crate::engine::Engine;
use crate::error::{Error, Result};
use crate::isa::ExecStats;
use crate::params::NetParams;
use crate::sensor::{Frame, FrameSource};

pub use crate::engine::{ArchSim, EngineConfig, ShardSlice};
pub use crate::engine::FrameOutput as FrameReport;

/// Coordinator configuration (alias of [`crate::engine::EngineConfig`]).
pub type CoordinatorConfig = EngineConfig;

/// Aggregate over a run.
#[derive(Clone, Debug, Default)]
pub struct RunSummary {
    pub frames: u64,
    pub exec: ExecStats,
    pub dpu: DpuStats,
    pub energy: EnergyBreakdown,
    pub total_arch_time_ns: f64,
    pub arch_mismatches: u64,
    /// Frames whose logits diverged from the cross-check reference
    /// backend (0 unless `engine.cross_check` is configured — and must
    /// stay 0 then, too).
    pub cross_check_mismatches: u64,
    /// Host wall-clock of the whole run [s].
    pub wall_seconds: f64,
    /// Hardware profile that priced `energy`/`total_arch_time_ns`
    /// (empty when nothing was modeled).
    pub hw_profile: String,
}

impl RunSummary {
    pub fn frames_per_second_modeled(&self) -> f64 {
        if self.total_arch_time_ns == 0.0 {
            return 0.0;
        }
        self.frames as f64 / (self.total_arch_time_ns * 1e-9)
    }

    pub fn energy_per_frame_uj(&self) -> f64 {
        if self.frames == 0 {
            return 0.0;
        }
        self.energy.total_pj() / 1e6 / self.frames as f64
    }
}

/// The coordinator.
pub struct Coordinator {
    pub params: NetParams,
    pub config: CoordinatorConfig,
}

impl Coordinator {
    pub fn new(params: NetParams, config: CoordinatorConfig) -> Result<Self> {
        config.validate()?;
        Ok(Self { params, config })
    }

    /// Build a fresh engine over this coordinator's parameters and
    /// configuration (one per worker/shard thread).
    pub fn engine(&self) -> Result<Engine> {
        Engine::builder()
            .config(self.config.clone())
            .params(self.params.clone())
            .build()
    }

    /// Compute sub-arrays available to this coordinator instance — the
    /// whole cache, or just this shard's bank slice.
    pub fn subarray_budget(&self) -> usize {
        self.config.subarray_budget()
    }

    /// A reusable per-shard processing handle bound to this coordinator's
    /// configuration (owns its engine, and through it the scratch
    /// sub-array, so the coordinator itself stays shareable).
    pub fn frame_handle(&self) -> Result<FrameHandle> {
        Ok(FrameHandle { engine: self.engine()? })
    }

    /// Run the pipeline over a frame source with worker-thread fan-out.
    pub fn run(&self, source: &mut dyn FrameSource, limit: usize)
               -> Result<(Vec<FrameReport>, RunSummary)> {
        // rolling shutter digitizes frames sequentially
        let mut frames = Vec::new();
        while frames.len() < limit {
            match source.next_frame() {
                Some(f) => frames.push(f),
                None => break,
            }
        }
        self.run_frames(&frames)
    }

    /// Run the pipeline over already-digitized frames with worker-thread
    /// fan-out.
    pub fn run_frames(&self, frames: &[Frame])
                      -> Result<(Vec<FrameReport>, RunSummary)> {
        let t0 = std::time::Instant::now();
        let workers = if self.config.system.workers > 0 {
            self.config.system.workers
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .min(frames.len().max(1))
        };
        let next = AtomicUsize::new(0);
        let mismatches = AtomicU64::new(0);
        let abort = AtomicBool::new(false);

        // Per-worker accumulators merged at join time — no lock on the
        // per-frame path; only the divergence counter is shared (atomic).
        let mut reports: Vec<FrameReport> = Vec::with_capacity(frames.len());
        let mut first_err: Option<Error> = None;
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    scope.spawn(|| {
                        let mut engine = match self.engine() {
                            Ok(e) => e,
                            Err(e) => {
                                abort.store(true, Ordering::Relaxed);
                                return (Vec::new(), Some(e));
                            }
                        };
                        let mut local: Vec<FrameReport> = Vec::new();
                        loop {
                            if abort.load(Ordering::Relaxed) {
                                break;
                            }
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= frames.len() {
                                break;
                            }
                            match engine.infer_frame(&frames[i]) {
                                Ok(report) => {
                                    mismatches.fetch_add(
                                        report.telemetry.arch_mismatches,
                                        Ordering::Relaxed,
                                    );
                                    local.push(report);
                                }
                                Err(e) => {
                                    abort.store(true, Ordering::Relaxed);
                                    return (local, Some(e));
                                }
                            }
                        }
                        (local, None)
                    })
                })
                .collect();
            for h in handles {
                match h.join() {
                    Ok((local, err)) => {
                        reports.extend(local);
                        if first_err.is_none() {
                            first_err = err;
                        }
                    }
                    Err(_) => {
                        if first_err.is_none() {
                            first_err = Some(Error::Coordinator(
                                "worker thread panicked".into(),
                            ));
                        }
                    }
                }
            }
        });
        if let Some(e) = first_err {
            return Err(e);
        }
        reports.sort_by_key(|r| r.seq);

        let mut summary = RunSummary {
            frames: reports.len() as u64,
            wall_seconds: t0.elapsed().as_secs_f64(),
            arch_mismatches: mismatches.load(Ordering::Relaxed),
            ..Default::default()
        };
        for r in &reports {
            summary.exec.merge(&r.telemetry.exec);
            summary.dpu.merge(&r.telemetry.dpu);
            summary.energy.add(&r.telemetry.cost.energy);
            summary.total_arch_time_ns += r.telemetry.cost.time_ns;
            summary.cross_check_mismatches +=
                r.telemetry.cross_check_mismatches;
            crate::engine::Telemetry::merge_profile_label(
                &mut summary.hw_profile,
                &r.telemetry.profile,
            );
        }
        debug_assert_eq!(
            summary.arch_mismatches,
            reports
                .iter()
                .map(|r| r.telemetry.arch_mismatches)
                .sum::<u64>(),
        );
        Ok((reports, summary))
    }
}

/// A reusable frame-processing handle: owns an engine (and through it the
/// scratch compute sub-array) so the coordinator itself stays shareable
/// (`&self`) across workers.  One handle per shard/worker thread; see
/// [`crate::serve::ShardPool`].
pub struct FrameHandle {
    engine: Engine,
}

impl FrameHandle {
    pub fn process(&mut self, frame: &Frame) -> Result<FrameReport> {
        self.engine.infer_frame(frame)
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::BackendKind;
    use crate::params::synth::synth_params;
    use crate::rng::Xoshiro256;
    use crate::sensor::{ReplaySensor, SensorConfig};

    fn setup(arch: ArchSim) -> (Coordinator, ReplaySensor) {
        let (_, params) = synth_params(5);
        let cfg = params.config;
        let mut sys = crate::config::SystemConfig::default();
        sys.workers = 2;
        let coord = Coordinator::new(
            params,
            CoordinatorConfig { system: sys, arch, shard: None },
        )
        .unwrap();
        let sensor_cfg = SensorConfig {
            rows: cfg.height,
            cols: cfg.width,
            channels: cfg.in_channels,
            skip_lsbs: cfg.apx_pixel,
            ..Default::default()
        };
        let mut rng = Xoshiro256::new(31);
        let scenes: Vec<Vec<f64>> = (0..6)
            .map(|_| (0..sensor_cfg.pixels()).map(|_| rng.next_f64()).collect())
            .collect();
        let sensor = ReplaySensor::new(sensor_cfg, scenes, 8).unwrap();
        (coord, sensor)
    }

    #[test]
    fn functional_pipeline_runs() {
        let (coord, mut sensor) = setup(ArchSim { lbp: false, mlp: false,
                                                  early_exit: false });
        let (reports, summary) = coord.run(&mut sensor, 6).unwrap();
        assert_eq!(reports.len(), 6);
        assert_eq!(summary.frames, 6);
        assert_eq!(summary.arch_mismatches, 0);
        // frames come back in order
        for (i, r) in reports.iter().enumerate() {
            assert_eq!(r.seq, i as u64);
            assert!(r.predicted < 10);
        }
    }

    #[test]
    fn architectural_path_matches_functional() {
        let (coord, mut sensor) = setup(ArchSim { lbp: true, mlp: true,
                                                  early_exit: false });
        let (reports, summary) = coord.run(&mut sensor, 3).unwrap();
        assert_eq!(summary.arch_mismatches, 0, "arch != functional");
        assert!(summary.exec.compute_ops > 0);
        assert!(summary.energy.total_pj() > 0.0);
        assert!(summary.total_arch_time_ns > 0.0);
        // logits equal to the purely functional run on the same frames
        let (coord_f, mut sensor_f) = setup(ArchSim { lbp: false, mlp: false,
                                                      early_exit: false });
        let (reports_f, _) = coord_f.run(&mut sensor_f, 3).unwrap();
        for (a, b) in reports.iter().zip(&reports_f) {
            assert_eq!(a.logits, b.logits, "frame {}", a.seq);
        }
    }

    #[test]
    fn functional_backend_selection_matches_architectural_logits() {
        // BackendKind::Functional through the coordinator: same logits,
        // no modeled hardware statistics
        let (mut coord, mut sensor) = setup(ArchSim::default());
        coord.config.system.engine.backend = BackendKind::Functional;
        let (reports, summary) = coord.run(&mut sensor, 2).unwrap();
        assert_eq!(summary.exec.compute_ops, 0);
        assert_eq!(summary.total_arch_time_ns, 0.0);
        let (coord_a, mut sensor_a) = setup(ArchSim::default());
        let (reports_a, _) = coord_a.run(&mut sensor_a, 2).unwrap();
        for (f, a) in reports.iter().zip(&reports_a) {
            assert_eq!(f.logits, a.logits);
        }
    }

    #[test]
    fn cross_check_reports_zero_mismatches() {
        let (mut coord, mut sensor) = setup(ArchSim::default());
        coord.config.system.engine.cross_check =
            Some(BackendKind::Functional);
        let (reports, summary) = coord.run(&mut sensor, 2).unwrap();
        assert_eq!(summary.cross_check_mismatches, 0);
        for r in &reports {
            assert_eq!(r.telemetry.cross_check_frames, 1);
            assert_eq!(r.telemetry.cross_check_mismatches, 0);
        }
    }

    #[test]
    fn early_exit_preserves_results_and_saves_cycles() {
        let (coord_e, mut sensor_e) = setup(ArchSim { lbp: true, mlp: false,
                                                      early_exit: true });
        let (reports_e, summary_e) = coord_e.run(&mut sensor_e, 2).unwrap();
        let (coord_n, mut sensor_n) = setup(ArchSim { lbp: true, mlp: false,
                                                      early_exit: false });
        let (reports_n, summary_n) = coord_n.run(&mut sensor_n, 2).unwrap();
        assert_eq!(summary_e.arch_mismatches, 0);
        for (a, b) in reports_e.iter().zip(&reports_n) {
            assert_eq!(a.logits, b.logits);
        }
        // early exit trades compute instructions for Ctrl reads; on random
        // data it must never *increase* the compute-op count
        assert!(summary_e.exec.compute_ops <= summary_n.exec.compute_ops);
        let _ = summary_n;
    }

    #[test]
    fn shard_slice_banks_partition_exactly() {
        for count in [1, 3, 4, 7, 80] {
            let total: usize = (0..count)
                .map(|index| ShardSlice { index, count }.banks(80))
                .sum();
            assert_eq!(total, 80, "count {count}");
        }
    }

    #[test]
    fn sharding_scales_modeled_time_not_results() {
        let (_, params) = synth_params(5);
        let mut sys = crate::config::SystemConfig::default();
        sys.workers = 1;
        let arch = ArchSim { lbp: true, mlp: false, early_exit: false };
        let full = Coordinator::new(
            params.clone(),
            CoordinatorConfig { system: sys.clone(), arch, shard: None },
        )
        .unwrap();
        let quarter = Coordinator::new(
            params,
            CoordinatorConfig {
                system: sys,
                arch,
                shard: Some(ShardSlice { index: 0, count: 4 }),
            },
        )
        .unwrap();
        assert_eq!(full.subarray_budget(), 320);
        assert_eq!(quarter.subarray_budget(), 80);

        let frame = {
            let (_, mut sensor) = setup(arch);
            sensor.next_frame().unwrap()
        };
        let mut hf = full.frame_handle().unwrap();
        let mut hq = quarter.frame_handle().unwrap();
        let rf = hf.process(&frame).unwrap();
        let rq = hq.process(&frame).unwrap();
        // functional results are shard-independent ...
        assert_eq!(rf.logits, rq.logits);
        assert_eq!(rf.telemetry.arch_mismatches, 0);
        assert_eq!(rq.telemetry.arch_mismatches, 0);
        // ... only the modeled accelerator time sees the smaller slice
        assert!(rq.telemetry.cost.time_ns >= rf.telemetry.cost.time_ns);
    }

    #[test]
    fn shard_slice_validation() {
        let (_, params) = synth_params(5);
        let bad = CoordinatorConfig {
            shard: Some(ShardSlice { index: 2, count: 2 }),
            ..Default::default()
        };
        assert!(Coordinator::new(params.clone(), bad).is_err());
        let too_many = CoordinatorConfig {
            shard: Some(ShardSlice { index: 0, count: 81 }),
            ..Default::default()
        };
        assert!(Coordinator::new(params, too_many).is_err());
    }

    #[test]
    fn frame_shape_mismatch_rejected() {
        let (coord, _) = setup(ArchSim::default());
        let bad = Frame { rows: 5, cols: 5, channels: 1, pixels: vec![0; 25],
                          seq: 0 };
        let mut handle = coord.frame_handle().unwrap();
        assert!(handle.process(&bad).is_err());
    }

    #[test]
    fn summary_metrics_consistent() {
        let (coord, mut sensor) = setup(ArchSim { lbp: true, mlp: false,
                                                  early_exit: false });
        let (reports, summary) = coord.run(&mut sensor, 4).unwrap();
        let sum_pj: f64 =
            reports.iter().map(|r| r.telemetry.cost.energy.total_pj()).sum();
        assert!((summary.energy.total_pj() - sum_pj).abs() < 1e-6);
        assert!(summary.energy_per_frame_uj() > 0.0);
        assert!(summary.frames_per_second_modeled() > 0.0);
        assert_eq!(summary.hw_profile, "ns_lbp_65nm");
    }
}
