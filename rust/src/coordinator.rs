//! The near-sensor coordinator: sensor → mapper → in-memory execution →
//! DPU → classification.
//!
//! This is the L3 runtime that ties the whole system together.  Each frame
//! flows through two redundant paths:
//!
//! * the **functional path** ([`crate::model`]) — fast bit-exact integer
//!   inference used for the logits, and
//! * the **architectural path** — the same LBP comparisons executed as
//!   Algorithm 1 over simulated compute sub-arrays
//!   ([`crate::lbp::parallel_compare`]) and, optionally, the MLP as
//!   in-memory AND/bitcount ([`crate::mlp`]), producing cycle/energy
//!   statistics *and* a per-frame equivalence check (any divergence is
//!   counted in [`FrameReport::arch_mismatches`] — it must be 0).
//!
//! Frames are independent, so the run loop fans out over worker threads
//! (std::thread — tokio is unavailable offline), each with its own
//! scratch sub-array; the modeled accelerator time still assumes the
//! paper's geometry (batches spread across the cache's sub-arrays).

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

use crate::config::SystemConfig;
use crate::dpu::{Dpu, DpuStats};
use crate::energy::{EnergyBreakdown, EnergyModel};
use crate::error::{Error, Result};
use crate::isa::{ExecStats, Executor};
use crate::lbp::parallel_compare;
use crate::mapping::LbpSubarrayMap;
use crate::mlp::MlpSubarrayMap;
use crate::model::{self, TensorU8};
use crate::params::{LbpLayer, NetParams};
use crate::sensor::{Frame, FrameSource};
use crate::sram::{Region, SubArray};

/// What the architectural path simulates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArchSim {
    /// Run every LBP comparison through the ISA-level Algorithm 1.
    pub lbp: bool,
    /// Run the MLP through the in-memory AND/bitcount path.
    pub mlp: bool,
    /// Let the Ctrl early-exit Algorithm 1 once all lanes are decided.
    pub early_exit: bool,
}

impl Default for ArchSim {
    fn default() -> Self {
        Self { lbp: true, mlp: false, early_exit: false }
    }
}

/// A shard's slice of the cache: shard `index` of `count` owns a disjoint
/// group of banks (the paper's parallelism unit), so concurrent shards
/// model concurrent traffic over *disjoint* compute sub-arrays instead of
/// all of them claiming the whole 2.5 MB slice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSlice {
    pub index: usize,
    pub count: usize,
}

impl ShardSlice {
    /// Banks owned by this shard out of `banks` total (remainder banks go
    /// to the lowest-indexed shards).
    pub fn banks(&self, banks: usize) -> usize {
        banks / self.count + usize::from(self.index < banks % self.count)
    }
}

/// Coordinator configuration.
#[derive(Clone, Debug, Default)]
pub struct CoordinatorConfig {
    pub system: SystemConfig,
    pub arch: ArchSim,
    /// When set, the modeled accelerator time assumes only this shard's
    /// bank slice is available (functional results are unaffected).
    pub shard: Option<ShardSlice>,
}

/// Per-frame outcome.
#[derive(Clone, Debug)]
pub struct FrameReport {
    pub seq: u64,
    pub predicted: usize,
    pub logits: Vec<f32>,
    pub exec: ExecStats,
    pub dpu: DpuStats,
    pub energy: EnergyBreakdown,
    /// Modeled accelerator latency for this frame [ns].
    pub arch_time_ns: f64,
    /// Architectural-vs-functional divergences (must be 0).
    pub arch_mismatches: u64,
}

/// Aggregate over a run.
#[derive(Clone, Debug, Default)]
pub struct RunSummary {
    pub frames: u64,
    pub exec: ExecStats,
    pub dpu: DpuStats,
    pub energy: EnergyBreakdown,
    pub total_arch_time_ns: f64,
    pub arch_mismatches: u64,
    /// Host wall-clock of the whole run [s].
    pub wall_seconds: f64,
}

impl RunSummary {
    pub fn frames_per_second_modeled(&self) -> f64 {
        if self.total_arch_time_ns == 0.0 {
            return 0.0;
        }
        self.frames as f64 / (self.total_arch_time_ns * 1e-9)
    }

    pub fn energy_per_frame_uj(&self) -> f64 {
        if self.frames == 0 {
            return 0.0;
        }
        self.energy.total_pj() / 1e6 / self.frames as f64
    }
}

/// The coordinator.
pub struct Coordinator {
    pub params: NetParams,
    pub config: CoordinatorConfig,
    pub energy_model: EnergyModel,
}

impl Coordinator {
    pub fn new(params: NetParams, config: CoordinatorConfig) -> Result<Self> {
        config.system.cache.validate()?;
        if let Some(s) = config.shard {
            if s.count == 0 || s.index >= s.count {
                return Err(Error::Coordinator(format!(
                    "shard slice {}/{} invalid", s.index, s.count
                )));
            }
            if s.count > config.system.cache.banks {
                return Err(Error::Coordinator(format!(
                    "{} shards cannot split {} banks",
                    s.count, config.system.cache.banks
                )));
            }
        }
        let mut em = EnergyModel::default();
        em.params.freq_ghz = config.system.circuit.freq_ghz;
        Ok(Self { params, config, energy_model: em })
    }

    /// Compute sub-arrays available to this coordinator instance — the
    /// whole cache, or just this shard's bank slice.
    pub fn subarray_budget(&self) -> usize {
        let g = &self.config.system.cache;
        match self.config.shard {
            None => g.total_subarrays(),
            Some(s) => s.banks(g.banks) * g.mats_per_bank * g.subarrays_per_mat,
        }
    }

    /// Lane order for one LBP layer: (y, x, kernel, sample≥apx).
    fn gather_pairs(&self, x: &TensorU8, layer: &LbpLayer) -> Vec<(u8, u8)> {
        let apx = self.params.config.apx_code;
        let mut pairs = Vec::with_capacity(
            x.h * x.w * layer.offsets.len() * (self.params.config.e - apx),
        );
        for y in 0..x.h {
            for xx in 0..x.w {
                for (k, pts) in layer.offsets.iter().enumerate() {
                    let pivot = x.get(y, xx, layer.pivot_ch[k] as usize);
                    for pt in pts.iter().skip(apx) {
                        let v = x.get_padded(
                            y as i64 + pt.dy as i64,
                            xx as i64 + pt.dx as i64,
                            pt.ch as usize,
                        );
                        pairs.push((v, pivot));
                    }
                }
            }
        }
        pairs
    }

    /// One LBP layer on the architectural path; returns the joint output
    /// and the number of bit mismatches against the functional path.
    fn lbp_layer_arch(&self, x: &TensorU8, layer: &LbpLayer, scratch: &mut SubArray,
                      map: &LbpSubarrayMap, exec: &mut ExecStats, dpu: &mut Dpu)
                      -> Result<(TensorU8, u64, f64)> {
        let cfg = &self.params.config;
        let apx = cfg.apx_code;
        let samples = cfg.e - apx;
        let pairs = self.gather_pairs(x, layer);
        let cols = scratch.cols();

        // run Algorithm 1 per ≤cols-lane batch on the scratch sub-array
        let mut bits = Vec::with_capacity(pairs.len());
        let mut batches = 0u64;
        for chunk in pairs.chunks(cols) {
            map.load_lanes(scratch, 0, chunk)?;
            exec.row_writes += 2 * map.bits as u64; // transposed lane load
            exec.cycles += 2 * map.bits as u64;
            let mut ex = Executor::new(scratch);
            let out = parallel_compare(&mut ex, map, 0, chunk.len(),
                                       cfg.apx_pixel,
                                       self.config.arch.early_exit)?;
            exec.merge(&ex.stats);
            bits.extend(out.bits);
            batches += 1;
        }

        // assemble codes in the same lane order and cross-check
        let k_n = layer.offsets.len();
        let mut out = TensorU8::zeros(x.h, x.w, x.c + k_n);
        let mut mismatches = 0u64;
        let mut lane = 0usize;
        for y in 0..x.h {
            for xx in 0..x.w {
                for ch in 0..x.c {
                    out.set(y, xx, ch, x.get(y, xx, ch));
                }
                for k in 0..k_n {
                    let mut code = 0u32;
                    for n in 0..samples {
                        if bits[lane + n] {
                            code |= 1 << (n + apx);
                        }
                    }
                    lane += samples;
                    let want = model::lbp_code(x, layer, k, y, xx, apx);
                    if code != want {
                        mismatches += 1;
                    }
                    out.set(y, xx, x.c + k, dpu.shifted_relu_u8(code, cfg.e as u32));
                }
            }
        }

        // modeled time: batches spread across this shard's sub-arrays
        let subarrays = self.subarray_budget() as f64;
        let cycles_per_batch = (2.0 * map.bits as f64)
            + 4.0 + 7.0 * (map.bits - cfg.apx_pixel) as f64 + 3.0;
        let time_ns = (batches as f64 / subarrays).ceil() * cycles_per_batch
            * self.energy_model.cycle_ns();
        Ok((out, mismatches, time_ns))
    }

    /// In-memory MLP layer (architectural); returns raw integer accums and
    /// mismatch count vs the functional matmul.
    fn mlp_layer_arch(&self, feats: &[u8], mlp: &crate::params::MlpLayer,
                      scratch: &mut SubArray, mmap: &MlpSubarrayMap,
                      exec: &mut ExecStats, dpu: &mut Dpu)
                      -> Result<(Vec<i64>, u64, f64)> {
        let cols = scratch.cols();
        let half = 1u8 << (self.params.config.w_bits - 1);
        let chunks: Vec<&[u8]> = feats.chunks(cols).collect();
        let mut accs = vec![0i64; mlp.o];
        let mut and_batches = 0u64;

        for (ci, chunk) in chunks.iter().enumerate() {
            let mut ex = Executor::new(scratch);
            mmap.load_vector(&mut ex, Region::Input, 0, chunk)?;
            let rowsum: i64 = chunk.iter().map(|&v| v as i64).sum();
            for o in 0..mlp.o {
                // weight column chunk, offset-stored unsigned
                let w_col: Vec<u8> = (0..chunk.len())
                    .map(|di| (mlp.weight(ci * cols + di, o) as i16 + half as i16) as u8)
                    .collect();
                mmap.load_vector(&mut ex, Region::Weight, 0, &w_col)?;
                accs[o] += mmap.dot_signed(&mut ex, dpu, 0, 0, chunk.len(),
                                           rowsum)?;
                and_batches += (mmap.act_bits * mmap.w_bits) as u64;
            }
            exec.merge(&ex.stats);
        }

        // cross-check against the functional integer matmul
        let want = model::int_matmul(feats, mlp);
        let mismatches = accs.iter().zip(&want).filter(|(a, w)| a != w).count() as u64;
        let subarrays = self.subarray_budget() as f64;
        let time_ns = (and_batches as f64 * 2.0 / subarrays).ceil()
            * self.energy_model.cycle_ns();
        Ok((accs, mismatches, time_ns))
    }

    /// Process one digitized frame.
    pub fn process_frame(&self, frame: &Frame, scratch: &mut SubArray)
                         -> Result<FrameReport> {
        let cfg = &self.params.config;
        if frame.rows != cfg.height || frame.cols != cfg.width
            || frame.channels != cfg.in_channels
        {
            return Err(Error::Coordinator(format!(
                "frame {}x{}x{} vs network {}x{}x{}",
                frame.rows, frame.cols, frame.channels,
                cfg.height, cfg.width, cfg.in_channels
            )));
        }
        let map = LbpSubarrayMap::new(self.config.system.cache.region, 8)?;
        let mut exec = ExecStats::default();
        let mut dpu = Dpu::default();
        let mut mismatches = 0u64;
        let mut arch_time_ns = 0.0;

        // the ADC already applied the pixel-LSB skip; mask again defensively
        let mask = 0xFFu8 ^ ((1u8 << cfg.apx_pixel).wrapping_sub(1));
        let data: Vec<u8> = frame.pixels.iter().map(|&p| p & mask).collect();
        let mut x = TensorU8 { h: cfg.height, w: cfg.width, c: cfg.in_channels,
                               data };

        // --- LBP layers -----------------------------------------------------
        for layer in &self.params.lbp_layers {
            if self.config.arch.lbp {
                let (nx, mm, t) =
                    self.lbp_layer_arch(&x, layer, scratch, &map, &mut exec,
                                        &mut dpu)?;
                mismatches += mm;
                arch_time_ns += t;
                x = nx;
            } else {
                x = model::lbp_layer_forward(&x, layer, cfg.e, cfg.apx_code,
                                             &mut dpu);
            }
        }

        // --- pooling + quantization (DPU) ------------------------------------
        let s = cfg.pool;
        let vmax = (255 * s * s) as u32;
        let (ph, pw) = (x.h / s, x.w / s);
        let mut feats = Vec::with_capacity(ph * pw * x.c);
        for py in 0..ph {
            for px in 0..pw {
                for ch in 0..x.c {
                    let mut sum = 0u32;
                    for dy in 0..s {
                        for dx in 0..s {
                            sum += x.get(py * s + dy, px * s + dx, ch) as u32;
                        }
                    }
                    feats.push(dpu.quantize_pooled(sum, vmax, cfg.act_bits as u32)?);
                }
            }
        }

        // --- MLP --------------------------------------------------------------
        let logits = if self.config.arch.mlp {
            let mmap = MlpSubarrayMap::new(map, cfg.act_bits, cfg.w_bits)?;
            let (acc1, mm1, t1) = self.mlp_layer_arch(&feats, &self.params.mlp1,
                                                      scratch, &mmap, &mut exec,
                                                      &mut dpu)?;
            mismatches += mm1;
            arch_time_ns += t1;
            let hidden: Vec<u8> = acc1.iter().enumerate()
                .map(|(o, &h)| dpu.activation(h, self.params.mlp1.scale[o],
                                              self.params.mlp1.bias[o],
                                              cfg.act_bits as u32))
                .collect();
            let (acc2, mm2, t2) = self.mlp_layer_arch(&hidden, &self.params.mlp2,
                                                      scratch, &mmap, &mut exec,
                                                      &mut dpu)?;
            mismatches += mm2;
            arch_time_ns += t2;
            acc2.iter().enumerate()
                .map(|(o, &h)| dpu.affine(h, self.params.mlp2.scale[o],
                                          self.params.mlp2.bias[o]))
                .collect()
        } else {
            model::mlp_forward(&self.params, &feats, &mut dpu)?
        };

        // --- energy ------------------------------------------------------------
        let mut energy = self.energy_model.exec_energy(&exec);
        energy.add(&self.energy_model.dpu_energy(&dpu.stats));
        let pixels = (cfg.height * cfg.width * cfg.in_channels) as u64;
        energy.add(&self.energy_model.sensor_energy(pixels,
                                                    (8 - cfg.apx_pixel) as u64));

        Ok(FrameReport {
            seq: frame.seq,
            predicted: model::argmax(&logits),
            logits,
            exec,
            dpu: dpu.stats,
            energy,
            arch_time_ns,
            arch_mismatches: mismatches,
        })
    }

    /// A reusable per-shard processing handle bound to this coordinator.
    pub fn frame_handle(&self) -> FrameHandle<'_> {
        let g = &self.config.system.cache;
        FrameHandle { coord: self, scratch: SubArray::new(g.rows, g.cols) }
    }

    /// Run the pipeline over a frame source with worker-thread fan-out.
    pub fn run(&self, source: &mut dyn FrameSource, limit: usize)
               -> Result<(Vec<FrameReport>, RunSummary)> {
        let t0 = std::time::Instant::now();
        // rolling shutter digitizes frames sequentially
        let mut frames = Vec::new();
        while frames.len() < limit {
            match source.next_frame() {
                Some(f) => frames.push(f),
                None => break,
            }
        }
        let workers = if self.config.system.workers > 0 {
            self.config.system.workers
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .min(frames.len().max(1))
        };
        let next = AtomicUsize::new(0);
        let mismatches = AtomicU64::new(0);
        let abort = AtomicBool::new(false);

        // Per-worker accumulators merged at join time — no lock on the
        // per-frame path; only the divergence counter is shared (atomic).
        let mut reports: Vec<FrameReport> = Vec::with_capacity(frames.len());
        let mut first_err: Option<Error> = None;
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    scope.spawn(|| {
                        let mut handle = self.frame_handle();
                        let mut local: Vec<FrameReport> = Vec::new();
                        loop {
                            if abort.load(Ordering::Relaxed) {
                                break;
                            }
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= frames.len() {
                                break;
                            }
                            match handle.process(&frames[i]) {
                                Ok(report) => {
                                    mismatches.fetch_add(report.arch_mismatches,
                                                         Ordering::Relaxed);
                                    local.push(report);
                                }
                                Err(e) => {
                                    abort.store(true, Ordering::Relaxed);
                                    return (local, Some(e));
                                }
                            }
                        }
                        (local, None)
                    })
                })
                .collect();
            for h in handles {
                match h.join() {
                    Ok((local, err)) => {
                        reports.extend(local);
                        if first_err.is_none() {
                            first_err = err;
                        }
                    }
                    Err(_) => {
                        if first_err.is_none() {
                            first_err = Some(Error::Coordinator(
                                "worker thread panicked".into(),
                            ));
                        }
                    }
                }
            }
        });
        if let Some(e) = first_err {
            return Err(e);
        }
        reports.sort_by_key(|r| r.seq);

        let mut summary = RunSummary {
            frames: reports.len() as u64,
            wall_seconds: t0.elapsed().as_secs_f64(),
            arch_mismatches: mismatches.load(Ordering::Relaxed),
            ..Default::default()
        };
        for r in &reports {
            summary.exec.merge(&r.exec);
            summary.dpu.merge(&r.dpu);
            summary.energy.add(&r.energy);
            summary.total_arch_time_ns += r.arch_time_ns;
        }
        debug_assert_eq!(
            summary.arch_mismatches,
            reports.iter().map(|r| r.arch_mismatches).sum::<u64>(),
        );
        Ok((reports, summary))
    }
}

/// A reusable frame-processing handle: owns the scratch compute sub-array
/// so the coordinator itself stays shareable (`&self`) across workers.
/// One handle per shard/worker thread; see [`crate::serve::ShardPool`].
pub struct FrameHandle<'c> {
    coord: &'c Coordinator,
    scratch: SubArray,
}

impl FrameHandle<'_> {
    pub fn process(&mut self, frame: &Frame) -> Result<FrameReport> {
        self.coord.process_frame(frame, &mut self.scratch)
    }

    pub fn coordinator(&self) -> &Coordinator {
        self.coord
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::synth::synth_params;
    use crate::rng::Xoshiro256;
    use crate::sensor::{ReplaySensor, SensorConfig};

    fn setup(arch: ArchSim) -> (Coordinator, ReplaySensor) {
        let (_, params) = synth_params(5);
        let cfg = params.config;
        let mut sys = SystemConfig::default();
        sys.workers = 2;
        let coord = Coordinator::new(
            params,
            CoordinatorConfig { system: sys, arch, shard: None },
        )
        .unwrap();
        let sensor_cfg = SensorConfig {
            rows: cfg.height,
            cols: cfg.width,
            channels: cfg.in_channels,
            skip_lsbs: cfg.apx_pixel,
            ..Default::default()
        };
        let mut rng = Xoshiro256::new(31);
        let scenes: Vec<Vec<f64>> = (0..6)
            .map(|_| (0..sensor_cfg.pixels()).map(|_| rng.next_f64()).collect())
            .collect();
        let sensor = ReplaySensor::new(sensor_cfg, scenes, 8).unwrap();
        (coord, sensor)
    }

    #[test]
    fn functional_pipeline_runs() {
        let (coord, mut sensor) = setup(ArchSim { lbp: false, mlp: false,
                                                  early_exit: false });
        let (reports, summary) = coord.run(&mut sensor, 6).unwrap();
        assert_eq!(reports.len(), 6);
        assert_eq!(summary.frames, 6);
        assert_eq!(summary.arch_mismatches, 0);
        // frames come back in order
        for (i, r) in reports.iter().enumerate() {
            assert_eq!(r.seq, i as u64);
            assert!(r.predicted < 10);
        }
    }

    #[test]
    fn architectural_path_matches_functional() {
        let (coord, mut sensor) = setup(ArchSim { lbp: true, mlp: true,
                                                  early_exit: false });
        let (reports, summary) = coord.run(&mut sensor, 3).unwrap();
        assert_eq!(summary.arch_mismatches, 0, "arch != functional");
        assert!(summary.exec.compute_ops > 0);
        assert!(summary.energy.total_pj() > 0.0);
        assert!(summary.total_arch_time_ns > 0.0);
        // logits equal to the purely functional run on the same frames
        let (coord_f, mut sensor_f) = setup(ArchSim { lbp: false, mlp: false,
                                                      early_exit: false });
        let (reports_f, _) = coord_f.run(&mut sensor_f, 3).unwrap();
        for (a, b) in reports.iter().zip(&reports_f) {
            assert_eq!(a.logits, b.logits, "frame {}", a.seq);
        }
    }

    #[test]
    fn early_exit_preserves_results_and_saves_cycles() {
        let (coord_e, mut sensor_e) = setup(ArchSim { lbp: true, mlp: false,
                                                      early_exit: true });
        let (reports_e, summary_e) = coord_e.run(&mut sensor_e, 2).unwrap();
        let (coord_n, mut sensor_n) = setup(ArchSim { lbp: true, mlp: false,
                                                      early_exit: false });
        let (reports_n, summary_n) = coord_n.run(&mut sensor_n, 2).unwrap();
        assert_eq!(summary_e.arch_mismatches, 0);
        for (a, b) in reports_e.iter().zip(&reports_n) {
            assert_eq!(a.logits, b.logits);
        }
        // early exit trades compute instructions for Ctrl reads; on random
        // data it must never *increase* the compute-op count
        assert!(summary_e.exec.compute_ops <= summary_n.exec.compute_ops);
        let _ = summary_n;
    }

    #[test]
    fn shard_slice_banks_partition_exactly() {
        for count in [1, 3, 4, 7, 80] {
            let total: usize = (0..count)
                .map(|index| ShardSlice { index, count }.banks(80))
                .sum();
            assert_eq!(total, 80, "count {count}");
        }
    }

    #[test]
    fn sharding_scales_modeled_time_not_results() {
        let (_, params) = synth_params(5);
        let mut sys = SystemConfig::default();
        sys.workers = 1;
        let arch = ArchSim { lbp: true, mlp: false, early_exit: false };
        let full = Coordinator::new(
            params.clone(),
            CoordinatorConfig { system: sys.clone(), arch, shard: None },
        )
        .unwrap();
        let quarter = Coordinator::new(
            params,
            CoordinatorConfig {
                system: sys,
                arch,
                shard: Some(ShardSlice { index: 0, count: 4 }),
            },
        )
        .unwrap();
        assert_eq!(full.subarray_budget(), 320);
        assert_eq!(quarter.subarray_budget(), 80);

        let frame = {
            let (_, mut sensor) = setup(arch);
            sensor.next_frame().unwrap()
        };
        let mut hf = full.frame_handle();
        let mut hq = quarter.frame_handle();
        let rf = hf.process(&frame).unwrap();
        let rq = hq.process(&frame).unwrap();
        // functional results are shard-independent ...
        assert_eq!(rf.logits, rq.logits);
        assert_eq!(rf.arch_mismatches, 0);
        assert_eq!(rq.arch_mismatches, 0);
        // ... only the modeled accelerator time sees the smaller slice
        assert!(rq.arch_time_ns >= rf.arch_time_ns);
    }

    #[test]
    fn shard_slice_validation() {
        let (_, params) = synth_params(5);
        let bad = CoordinatorConfig {
            shard: Some(ShardSlice { index: 2, count: 2 }),
            ..Default::default()
        };
        assert!(Coordinator::new(params.clone(), bad).is_err());
        let too_many = CoordinatorConfig {
            shard: Some(ShardSlice { index: 0, count: 81 }),
            ..Default::default()
        };
        assert!(Coordinator::new(params, too_many).is_err());
    }

    #[test]
    fn frame_shape_mismatch_rejected() {
        let (coord, _) = setup(ArchSim::default());
        let bad = Frame { rows: 5, cols: 5, channels: 1, pixels: vec![0; 25],
                          seq: 0 };
        let g = &coord.config.system.cache;
        let mut scratch = SubArray::new(g.rows, g.cols);
        assert!(coord.process_frame(&bad, &mut scratch).is_err());
    }

    #[test]
    fn summary_metrics_consistent() {
        let (coord, mut sensor) = setup(ArchSim { lbp: true, mlp: false,
                                                  early_exit: false });
        let (reports, summary) = coord.run(&mut sensor, 4).unwrap();
        let sum_pj: f64 = reports.iter().map(|r| r.energy.total_pj()).sum();
        assert!((summary.energy.total_pj() - sum_pj).abs() < 1e-6);
        assert!(summary.energy_per_frame_uj() > 0.0);
        assert!(summary.frames_per_second_modeled() > 0.0);
    }
}
