//! Table 1: hardware cost analysis of CNN vs Ap-LBP (computational and
//! memory cost of one layer), plus the Eq. 1 / Eq. 2 whole-network totals
//! and the worked Fig. 3(b) example.

use ns_lbp::bench_harness::Table;
use ns_lbp::lbp::opcount::{ApLbpOps, LayerShape, LbpCost};

fn main() {
    println!("== Table 1: hardware cost, CNN vs Ap-LBP ==\n");

    // the paper's symbolic table instantiated at both network shapes
    for (name, p, q, ch) in [("mnist L1", 28u64, 28u64, 9u64),
                             ("svhn L4", 32, 32, 27)] {
        let shape = LayerShape { p, q, ch, r: 3, s: 3 };
        let cnn = shape.cnn_cost();
        let ap0 = shape.aplbp_cost(8, 8, 0);
        let ap2 = shape.aplbp_cost(8, 8, 2);
        let mut t = Table::new(&["network", "Mul (O(N²))", "Add/Sub/Cmp (O(N))",
                                 "memory"]);
        t.row(&["CNN".into(), cnn.muls.to_string(), cnn.adds.to_string(),
                cnn.memory.to_string()]);
        t.row(&["Ap-LBP apx=0 (LBPNet)".into(), "0".into(),
                ap0.adds.to_string(), ap0.memory.to_string()]);
        t.row(&["Ap-LBP apx=2".into(), "0".into(), ap2.adds.to_string(),
                ap2.memory.to_string()]);
        println!("layer shape {name}: p={p} q={q} ch={ch} r=s=3, e=8 m=8");
        t.print();
        println!();
    }

    // Fig. 3(b) worked example — the paper's own numbers
    println!("== Fig. 3(b) worked example (e=5, ch=2, m=4, apx=1) ==\n");
    let c = LbpCost { e: 5, ch: 2, m: 4, apx: 1 };
    let mut t = Table::new(&["", "reads", "comparisons", "writes"]);
    let l = c.lbpnet_ops();
    let a = c.aplbp_ops();
    t.row(&["LBPNet (paper: 14/8/12)".into(), l.reads.to_string(),
            l.comparisons.to_string(), l.writes.to_string()]);
    t.row(&["Ap-LBP (paper: 11/6/9)".into(), a.reads.to_string(),
            a.comparisons.to_string(), a.writes.to_string()]);
    t.print();
    assert_eq!((l.reads, l.comparisons, l.writes), (14, 8, 12));
    assert_eq!((a.reads, a.comparisons, a.writes), (11, 6, 9));
    println!("\nmatches the paper exactly.\n");

    // Eq. 1/2 whole-network totals
    println!("== Eq. 1 / Eq. 2 per-image totals ==\n");
    let mut t = Table::new(&["network", "reads", "comparisons", "writes",
                             "total", "saving"]);
    for ds in ["mnist", "svhn"] {
        for apx in [0u64, 1, 2] {
            let net = ApLbpOps::for_dataset(ds, apx).unwrap();
            let ops = if apx == 0 { net.total_lbpnet() } else { net.total_aplbp() };
            let base = net.total_lbpnet().total() as f64;
            t.row(&[
                format!("{ds} apx={apx}"),
                ops.reads.to_string(),
                ops.comparisons.to_string(),
                ops.writes.to_string(),
                ops.total().to_string(),
                format!("{:.1}%", 100.0 * (1.0 - ops.total() as f64 / base)),
            ]);
        }
    }
    t.print();
    std::fs::create_dir_all("artifacts/results").ok();
    t.write_tsv("artifacts/results/table1.tsv").unwrap();
    println!("\nwrote artifacts/results/table1.tsv");
}
