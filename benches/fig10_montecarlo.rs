//! Fig. 10: Monte-Carlo simulation of RBL and SA reference voltage.
//!
//! Regenerates the sense-margin analysis — all 256 bit-lines, 200 trials,
//! all bit combinations, process + mismatch variation — at the paper's
//! operating points, and reports the minimum V_Ref placement window
//! (paper: ~92 mV between the "111" and "011" clusters at 1.1 V).

use ns_lbp::bench_harness::{Bench, Table};
use ns_lbp::circuit::{CircuitParams, MonteCarlo};

fn main() {
    println!("== Fig. 10: Monte-Carlo RBL / V_Ref margins ==\n");
    let mut table = Table::new(&["VDD [V]", "level means [V]",
                                 "gap 000-001 [mV]", "gap 001-011 [mV]",
                                 "gap 011-111 [mV]", "min margin [mV]",
                                 "decision errors"]);
    for vdd in [0.9, 1.0, 1.1] {
        let params = CircuitParams { vdd, ..CircuitParams::default() };
        let r = MonteCarlo::new(params).run(7);
        table.row(&[
            format!("{vdd:.1}"),
            format!("{:.2}/{:.2}/{:.2}/{:.2}", r.levels[0].mean,
                    r.levels[1].mean, r.levels[2].mean, r.levels[3].mean),
            format!("{:.1}", r.level_gaps[0] * 1e3),
            format!("{:.1}", r.level_gaps[1] * 1e3),
            format!("{:.1}", r.level_gaps[2] * 1e3),
            format!("{:.1}", r.min_margin * 1e3),
            format!("{:.1e}", r.decision_error_rate),
        ]);
    }
    table.print();
    println!("\npaper @1.1 V: ~92 mV min margin, higher VDD ⇒ larger margin,");
    println!("lower VDD limits max frequency via the shrinking V_Ref range.");

    std::fs::create_dir_all("artifacts/results").ok();
    table.write_tsv("artifacts/results/fig10.tsv").unwrap();
    println!("wrote artifacts/results/fig10.tsv\n");

    // --- distribution detail at the paper's nominal point -------------------
    let r = MonteCarlo::default().run(7);
    let mut lanes = Table::new(&["lane", "mean [mV]", "std [mV]", "min [mV]"]);
    for l in &r.lanes {
        lanes.row(&[
            format!("{}{} V_R{}", "1".repeat(l.ones),
                    if l.above { ">" } else { "<" }, l.reference + 1),
            format!("{:.1}", l.stats.mean * 1e3),
            format!("{:.1}", l.stats.std * 1e3),
            format!("{:.1}", l.stats.min * 1e3),
        ]);
    }
    lanes.print();

    // --- throughput of the MC engine (perf instrument) ---------------------
    let mut b = Bench::new("fig10");
    b.run("mc_200x256_full", || MonteCarlo::default().run(9).min_margin);
}
