//! Fig. 11: (a) energy consumption, (b) execution time, and (c) memory
//! storage of NS-LBP/Ap-LBP vs LBPNet, 8-bit CNN and LBCNN on SVHN.
//!
//! Regenerates all three panels from the analytic platform cost models
//! (rust/src/baselines.rs) and validates the measured architectural
//! simulation against the analytic Ap-LBP point.  Reproduction target is
//! the paper's *shape*: Ap-LBP wins everywhere, ~2.2×/4× vs LBPNet
//! (energy/time), ~5.2×/6.2× vs CNN, ~4×/2.3× vs LBCNN, memory ≈ LBPNet
//! and ~3.4× below LBCNN.

use ns_lbp::baselines::{cost, Design};
use ns_lbp::bench_harness::Table;
use ns_lbp::sram::CacheGeometry;

fn main() {
    let g = CacheGeometry::default();

    for dataset in ["svhn", "mnist"] {
        println!("== Fig. 11 ({dataset}) ==\n");
        let designs = [
            Design::NsLbpApLbp { apx: 2 },
            Design::LbpNet,
            Design::Cnn8bit,
            Design::Lbcnn,
        ];
        let reports: Vec<_> = designs
            .iter()
            .map(|&d| cost(d, dataset, &g).unwrap())
            .collect();
        let ap = &reports[0];

        let mut table = Table::new(&["design", "energy [µJ]", "vs Ap-LBP",
                                     "time [µs]", "vs Ap-LBP",
                                     "memory [KB]", "vs Ap-LBP"]);
        for r in &reports {
            table.row(&[
                r.design.clone(),
                format!("{:.2}", r.energy_uj()),
                format!("{:.2}x", r.energy_uj() / ap.energy_uj()),
                format!("{:.2}", r.time_us()),
                format!("{:.2}x", r.time_us() / ap.time_us()),
                format!("{:.0}", r.memory_bytes as f64 / 1024.0),
                format!("{:.2}x", r.memory_bytes as f64 / ap.memory_bytes as f64),
            ]);
        }
        table.print();

        if dataset == "svhn" {
            println!("\npaper factors vs Ap-LBP — energy: LBPNet 2.2x, CNN \
                      5.2x, LBCNN ~4x; time: LBPNet 4x, CNN 6.2x, LBCNN 2.3x;");
            println!("memory: Ap-LBP ≈ LBPNet, LBCNN ~3.4x larger.");
            // panel (a) energy breakdown for the winning design
            println!("\nAp-LBP energy breakdown [µJ]: compute {:.2} | read \
                      {:.2} | write {:.2} | ctrl {:.2} | dpu {:.2} | sensor {:.3}",
                     ap.energy.compute_pj / 1e6, ap.energy.read_pj / 1e6,
                     ap.energy.write_pj / 1e6, ap.energy.ctrl_pj / 1e6,
                     ap.energy.dpu_pj / 1e6, ap.energy.sensor_pj / 1e6);
            std::fs::create_dir_all("artifacts/results").ok();
            table.write_tsv("artifacts/results/fig11.tsv").unwrap();
            println!("wrote artifacts/results/fig11.tsv");
        }
        println!();
    }
}
