//! Hot-path micro-benchmarks — the perf-pass instrument (EXPERIMENTS.md
//! §Perf).  Covers every stage of the L3 pipeline:
//!
//! * sub-array bulk-bitwise row ops (the single-cycle compute primitive),
//! * a full Algorithm-1 256-lane batch,
//! * lane loading (transposed bit-plane writes),
//! * the in-memory bit-serial dot product,
//! * partitioning, Monte-Carlo trials, and a whole functional-model frame.

use ns_lbp::bench_harness::{black_box, Bench};
use ns_lbp::circuit::MonteCarlo;
use ns_lbp::dpu::Dpu;
use ns_lbp::isa::{Executor, Instruction};
use ns_lbp::lbp::parallel_compare;
use ns_lbp::mapping::{partition, LbpSubarrayMap};
use ns_lbp::mlp::MlpSubarrayMap;
use ns_lbp::model;
use ns_lbp::params;
use ns_lbp::rng::Xoshiro256;
use ns_lbp::sram::{CacheGeometry, Region, RegionLayout, SubArray};

fn main() {
    let mut b = Bench::new("hotpath");
    let map = LbpSubarrayMap::new(RegionLayout::default(), 8).unwrap();
    let mut rng = Xoshiro256::new(1);

    // --- raw row ops ---------------------------------------------------------
    {
        let mut sa = SubArray::new(256, 256);
        for r in 0..3 {
            let words: Vec<u64> = (0..4).map(|_| rng.next_u64()).collect();
            sa.write_row(r, &words).unwrap();
        }
        let mut ex = Executor::new(&mut sa);
        b.run("isa_sum3_row_op", || {
            ex.exec(Instruction::Sum { src1: 0, src2: 1, src3: 2, dest: 5 })
                .unwrap();
            ex.stats.instructions
        });
    }

    // --- Algorithm 1, full 256-lane batch ------------------------------------
    {
        let pairs: Vec<(u8, u8)> = (0..256)
            .map(|_| (rng.next_u64() as u8, rng.next_u64() as u8))
            .collect();
        let mut sa = SubArray::new(256, 256);
        map.load_lanes(&mut sa, 0, &pairs).unwrap();
        b.run("alg1_compare_256lanes", || {
            let mut ex = Executor::new(&mut sa);
            parallel_compare(&mut ex, &map, 0, 256, 0, false).unwrap().bits
        });
        let mut sa2 = SubArray::new(256, 256);
        b.run("lane_load_256x8bit", || {
            map.load_lanes(&mut sa2, 0, black_box(&pairs)).unwrap()
        });
    }

    // --- in-memory bit-serial dot --------------------------------------------
    {
        let mmap = MlpSubarrayMap::new(map, 4, 4).unwrap();
        let x: Vec<u8> = (0..256).map(|_| rng.next_u64() as u8 & 15).collect();
        let w: Vec<u8> = (0..256).map(|_| rng.next_u64() as u8 & 15).collect();
        let mut sa = SubArray::new(256, 256);
        let mut ex = Executor::new(&mut sa);
        mmap.load_vector(&mut ex, Region::Input, 0, &x).unwrap();
        mmap.load_vector(&mut ex, Region::Weight, 0, &w).unwrap();
        b.run("bitserial_dot_256lanes", || {
            let mut dpu = Dpu::default();
            mmap.dot_unsigned(&mut ex, &mut dpu, 0, 0, 256).unwrap()
        });
    }

    // --- partitioning ---------------------------------------------------------
    {
        let g = CacheGeometry::default();
        let pairs: Vec<(u8, u8)> = (0..50_176) // one MNIST layer of lanes
            .map(|_| (rng.next_u64() as u8, rng.next_u64() as u8))
            .collect();
        b.run("partition_50k_lanes", || {
            partition(black_box(&pairs), &g, &map).unwrap().len()
        });
    }

    // --- Monte-Carlo ------------------------------------------------------------
    b.run("montecarlo_20x256", || {
        let mc = MonteCarlo { trials: 20, ..MonteCarlo::default() };
        mc.run(3).min_margin
    });

    // --- whole frames ------------------------------------------------------------
    if let Ok(p) = params::load("artifacts/mnist.params.bin") {
        let cfg = p.config;
        let img: Vec<f32> = (0..cfg.height * cfg.width * cfg.in_channels)
            .map(|_| rng.next_f64() as f32)
            .collect();
        b.run("functional_frame_mnist", || {
            model::apply(&p, black_box(&img), &mut Dpu::default()).unwrap()
        });
        use ns_lbp::coordinator::{ArchSim, Coordinator, CoordinatorConfig};
        use ns_lbp::sensor::Frame;
        let coord = Coordinator::new(
            p.clone(),
            CoordinatorConfig { arch: ArchSim::default(), ..Default::default() },
        )
        .unwrap();
        let q = model::sensor_quantize(&img, cfg.apx_pixel);
        let frame = Frame { rows: cfg.height, cols: cfg.width,
                            channels: cfg.in_channels, pixels: q, seq: 0 };
        let mut handle = coord.frame_handle().unwrap();
        b.run("architectural_frame_mnist", || {
            handle.process(black_box(&frame)).unwrap().seq
        });
    } else {
        eprintln!("(skipping whole-frame benches: run `make artifacts`)");
    }
}
