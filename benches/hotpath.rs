//! Hot-path micro-benchmarks — the perf-pass instrument (see
//! EXPERIMENTS.md §Perf for how to run and read it).  Covers every stage
//! of the L3 pipeline:
//!
//! * sub-array bulk-bitwise row ops (the single-cycle compute primitive),
//! * a full Algorithm-1 256-lane batch,
//! * lane loading (transposed bit-plane writes),
//! * the in-memory bit-serial dot product — in both the seed shape
//!   (per-call weight collect + transpose + load) and the shipped shape
//!   (prepacked `WeightPlanes`), so the prepack speedup is measured
//!   in-run,
//! * whole architectural frames, cold (fresh backend per frame — the
//!   seed-shaped allocating path) vs warm (persistent scratch arena),
//!   plus an 8-frame batch — the unit a serve shard dispatches — with
//!   the tracing instrumentation measured disabled (CI gates it within
//!   2% or noise of the default path) and enabled (informational),
//! * partitioning, Monte-Carlo trials, and a whole functional-model
//!   frame.
//!
//! `--json[=PATH]` additionally writes the results as
//! `BENCH_hotpath.json` (default) — the trajectory artifact CI uploads
//! every run and diffs against the previous run's upload.

use ns_lbp::bench_harness::{black_box, Bench};

// With `--features alloc-count` the whole binary runs on the counting
// allocator so the steady-state gate below can prove the warm dispatch
// path allocates nothing beyond the output value it returns.
#[cfg(feature = "alloc-count")]
#[global_allocator]
static COUNTING_ALLOC: ns_lbp::bench_harness::alloc_count::CountingAlloc =
    ns_lbp::bench_harness::alloc_count::CountingAlloc;
use ns_lbp::circuit::MonteCarlo;
use ns_lbp::dpu::Dpu;
use ns_lbp::engine::{ArchSim, ArchitecturalBackend, EngineConfig,
                     InferenceBackend};
use ns_lbp::isa::{Executor, Instruction};
use ns_lbp::lbp::parallel_compare;
use ns_lbp::mapping::{partition, LbpSubarrayMap};
use ns_lbp::mlp::{MlpSubarrayMap, WeightPlanes};
use ns_lbp::model;
use ns_lbp::params;
use ns_lbp::params::MlpLayer;
use ns_lbp::rng::Xoshiro256;
use ns_lbp::sram::{CacheGeometry, Region, RegionLayout, SubArray};
use ns_lbp::testing::synth_frames;

fn main() {
    let mut json_path: Option<String> = None;
    for arg in std::env::args().skip(1) {
        if arg == "--json" {
            json_path = Some("BENCH_hotpath.json".into());
        } else if let Some(p) = arg.strip_prefix("--json=") {
            json_path = Some(p.to_string());
        }
        // anything else (e.g. cargo's own bench flags) is ignored
    }

    let mut b = Bench::new("hotpath");
    let map = LbpSubarrayMap::new(RegionLayout::default(), 8).unwrap();
    let mut rng = Xoshiro256::new(1);

    // --- raw row ops ---------------------------------------------------------
    {
        let mut sa = SubArray::new(256, 256);
        for r in 0..3 {
            let words: Vec<u64> = (0..4).map(|_| rng.next_u64()).collect();
            sa.write_row(r, &words).unwrap();
        }
        let mut ex = Executor::new(&mut sa);
        b.run("isa_sum3_row_op", || {
            ex.exec(Instruction::Sum { src1: 0, src2: 1, src3: 2, dest: 5 })
                .unwrap();
            ex.stats.instructions
        });
    }

    // --- Algorithm 1, full 256-lane batch ------------------------------------
    {
        let pairs: Vec<(u8, u8)> = (0..256)
            .map(|_| (rng.next_u64() as u8, rng.next_u64() as u8))
            .collect();
        let mut sa = SubArray::new(256, 256);
        map.load_lanes(&mut sa, 0, &pairs).unwrap();
        b.run("alg1_compare_256lanes", || {
            let mut ex = Executor::new(&mut sa);
            parallel_compare(&mut ex, &map, 0, 256, 0, false).unwrap().bits
        });
        let mut sa2 = SubArray::new(256, 256);
        b.run("lane_load_256x8bit", || {
            map.load_lanes(&mut sa2, 0, black_box(&pairs)).unwrap()
        });
        // persistent staging buffer — the arena-threaded shape the
        // architectural batch path actually runs
        let mut planes = Vec::new();
        b.run("lane_load_256x8bit_warm", || {
            map.load_lanes_with(&mut sa2, 0, black_box(&pairs), &mut planes)
                .unwrap()
        });
    }

    // --- in-memory bit-serial dot --------------------------------------------
    {
        let mmap = MlpSubarrayMap::new(map, 4, 4).unwrap();
        let x: Vec<u8> = (0..256).map(|_| rng.next_u64() as u8 & 15).collect();
        let w: Vec<u8> = (0..256).map(|_| rng.next_u64() as u8 & 15).collect();
        let mut sa = SubArray::new(256, 256);
        let mut ex = Executor::new(&mut sa);
        mmap.load_vector(&mut ex, Region::Input, 0, &x).unwrap();
        mmap.load_vector(&mut ex, Region::Weight, 0, &w).unwrap();
        b.run("bitserial_dot_256lanes", || {
            let mut dpu = Dpu::default();
            mmap.dot_unsigned(&mut ex, &mut dpu, 0, 0, 256).unwrap()
        });

        // before/after pair: the seed loaded the W region by collecting
        // and transposing a fresh weight column per output neuron
        // (`bitserial_dot_pack_percall`); the shipped path bulk-writes
        // bit-planes prepacked once at engine build
        // (`bitserial_dot_prepacked`).  Identical dots, different load.
        let layer = MlpLayer {
            d: 256,
            o: 1,
            w: (0..256).map(|_| (rng.next_u64() % 16) as i8 - 8).collect(),
            scale: vec![0.0],
            bias: vec![0.0],
        };
        let rowsum: i64 = x.iter().map(|&v| v as i64).sum();
        b.run("bitserial_dot_pack_percall", || {
            let w_col: Vec<u8> = (0..256)
                .map(|di| (layer.weight(di, 0) as i16 + 8) as u8)
                .collect();
            mmap.load_vector(&mut ex, Region::Weight, 0, &w_col).unwrap();
            let mut dpu = Dpu::default();
            mmap.dot_signed(&mut ex, &mut dpu, 0, 0, 256, rowsum).unwrap()
        });
        let planes = WeightPlanes::pack(&layer, 4, 256).unwrap();
        b.run("bitserial_dot_prepacked", || {
            mmap.load_weight_planes(&mut ex, 0, black_box(&planes), 0, 0)
                .unwrap();
            let mut dpu = Dpu::default();
            mmap.dot_signed(&mut ex, &mut dpu, 0, 0, 256, rowsum).unwrap()
        });
    }

    // --- partitioning ---------------------------------------------------------
    {
        let g = CacheGeometry::default();
        let pairs: Vec<(u8, u8)> = (0..50_176) // one MNIST layer of lanes
            .map(|_| (rng.next_u64() as u8, rng.next_u64() as u8))
            .collect();
        b.run("partition_50k_lanes", || {
            partition(black_box(&pairs), &g, &map).unwrap().len()
        });
    }

    // --- Monte-Carlo ------------------------------------------------------------
    b.run("montecarlo_20x256", || {
        let mc = MonteCarlo { trials: 20, ..MonteCarlo::default() };
        mc.run(3).min_margin
    });

    // --- whole architectural frames (synthetic net, always available) --------
    // cold = a fresh backend per frame: re-packs the weight planes,
    // re-builds the maps, and grows a new arena — the shape of the seed's
    // per-frame allocating path.  warm = the shipped steady state: one
    // backend, persistent arena.  batch8 = the unit one serve shard
    // dispatches per `Engine::infer_batch`.
    {
        let (_, p) = params::synth::synth_params(5);
        let frames = synth_frames(&p, 8, 7).unwrap();
        let config = EngineConfig {
            arch: ArchSim { lbp: true, mlp: true, early_exit: false },
            ..Default::default()
        };
        b.run("arch_frame_synth_cold", || {
            let mut be =
                ArchitecturalBackend::new(p.clone(), config.clone()).unwrap();
            be.infer_batch(std::slice::from_ref(&frames[0]))
                .unwrap()
                .frames
                .len()
        });
        let mut warm =
            ArchitecturalBackend::new(p.clone(), config.clone()).unwrap();
        b.run("arch_frame_synth_warm", || {
            warm.infer_batch(std::slice::from_ref(black_box(&frames[0])))
                .unwrap()
                .frames
                .len()
        });
        b.run("arch_batch8_dispatch", || {
            warm.infer_batch(black_box(&frames)).unwrap().frames.len()
        });
        // --- steady-state allocation gate (alloc-count builds only) ----
        // The warm dispatch may allocate only what the returned
        // `BackendOutput` inherently owns (per-frame logits / features /
        // profile string — measured as the cost of cloning one output)
        // plus a handful of batch-local collector vectors.  A regression
        // back to the seed's per-dispatch shape (fresh backend, per-call
        // weight packs, unpooled arenas) costs hundreds of allocations
        // and trips the bound; per-iteration drift trips the steadiness
        // check.
        #[cfg(feature = "alloc-count")]
        {
            use ns_lbp::bench_harness::alloc_count;
            let out = warm.infer_batch(&frames).unwrap();
            let (_, baseline) = alloc_count::count(|| black_box(out.clone()));
            let rounds: Vec<u64> = (0..3)
                .map(|_| {
                    let (o, n) = alloc_count::count(|| {
                        warm.infer_batch(black_box(&frames)).unwrap()
                    });
                    black_box(o);
                    n
                })
                .collect();
            assert_eq!(
                rounds[1], rounds[2],
                "warm dispatch allocation count drifts between iterations \
                 ({rounds:?}) — the steady state is leaking"
            );
            let budget = baseline + 2 * frames.len() as u64 + 8;
            assert!(
                rounds[2] <= budget,
                "warm dispatch allocates {} per batch (output baseline {}, \
                 budget {}) — the zero-alloc hot path regressed",
                rounds[2], baseline, budget
            );
            println!(
                "alloc gate: {} allocs/dispatch (output baseline {}, \
                 budget {}) — steady",
                rounds[2], baseline, budget
            );
        }
        // tracing cost on the dispatch unit: `trace_off` pins an
        // explicitly disabled tracer and must be indistinguishable from
        // the default path above — CI gates the pair within 2% or noise
        // (3x MAD), so the disabled-tracer branches stay free on the hot
        // path.  `trace_on` is informational: spans land in an undrained
        // ring, the worst case for emit contention.
        warm.set_tracer(ns_lbp::obs::Tracer::disabled());
        b.run("arch_batch8_dispatch_trace_off", || {
            warm.infer_batch(black_box(&frames)).unwrap().frames.len()
        });
        warm.set_tracer(ns_lbp::obs::Tracer::new(1 << 16));
        b.run("arch_batch8_dispatch_trace_on", || {
            warm.infer_batch(black_box(&frames)).unwrap().frames.len()
        });
        warm.set_tracer(ns_lbp::obs::Tracer::disabled());
    }

    // --- whole frames (artifact-gated MNIST net) ------------------------------
    if let Ok(p) = params::load("artifacts/mnist.params.bin") {
        let cfg = p.config;
        let img: Vec<f32> = (0..cfg.height * cfg.width * cfg.in_channels)
            .map(|_| rng.next_f64() as f32)
            .collect();
        b.run("functional_frame_mnist", || {
            model::apply(&p, black_box(&img), &mut Dpu::default()).unwrap()
        });
        use ns_lbp::coordinator::{Coordinator, CoordinatorConfig};
        use ns_lbp::sensor::Frame;
        let coord = Coordinator::new(
            p.clone(),
            CoordinatorConfig { arch: ArchSim::default(), ..Default::default() },
        )
        .unwrap();
        let q = model::sensor_quantize(&img, cfg.apx_pixel);
        let frame = Frame { rows: cfg.height, cols: cfg.width,
                            channels: cfg.in_channels, pixels: q, seq: 0 };
        let mut handle = coord.frame_handle().unwrap();
        b.run("architectural_frame_mnist", || {
            handle.process(black_box(&frame)).unwrap().seq
        });
    } else {
        eprintln!("(skipping MNIST whole-frame benches: run `make artifacts`)");
    }

    // --- before/after summary -------------------------------------------------
    if let (Some(before), Some(after)) = (
        b.result("bitserial_dot_pack_percall"),
        b.result("bitserial_dot_prepacked"),
    ) {
        println!(
            "prepacked weight planes: {:?} -> {:?} per dot ({:.2}x)",
            before.median,
            after.median,
            before.median.as_secs_f64() / after.median.as_secs_f64().max(1e-12)
        );
    }
    if let (Some(cold), Some(warm)) = (
        b.result("arch_frame_synth_cold"),
        b.result("arch_frame_synth_warm"),
    ) {
        println!(
            "warm arena arch frame: {:?} -> {:?} ({:.2}x)",
            cold.median,
            warm.median,
            cold.median.as_secs_f64() / warm.median.as_secs_f64().max(1e-12)
        );
    }
    if let (Some(base), Some(off), Some(on)) = (
        b.result("arch_batch8_dispatch"),
        b.result("arch_batch8_dispatch_trace_off"),
        b.result("arch_batch8_dispatch_trace_on"),
    ) {
        let pct = |a: f64, b: f64| (a / b.max(1e-12) - 1.0) * 100.0;
        println!(
            "tracing on batch8 dispatch: off {:+.2}% vs default, \
             on {:+.2}% vs off",
            pct(off.median.as_secs_f64(), base.median.as_secs_f64()),
            pct(on.median.as_secs_f64(), off.median.as_secs_f64()),
        );
    }

    if let Some(path) = json_path {
        b.write_json(&path).unwrap();
        println!("wrote {path}");
    }
}
