//! Serving-layer macro-benchmark: wall-clock throughput and tail latency
//! of the sharded, batching serve subsystem, swept over
//! backend × batch size × shard count on a fixed synthetic frame replay.
//! Every shard constructs its execution path through the engine layer
//! (`engine.backend`), so the same harness A/B-compares the functional
//! and architectural backends.
//!
//! ```bash
//! cargo bench --bench serve_throughput            # full sweep
//! NSLBP_BENCH_FAST=1 cargo bench --bench serve_throughput   # CI smoke
//! ```

use ns_lbp::bench_harness::Table;
use ns_lbp::config::SystemConfig;
use ns_lbp::coordinator::{ArchSim, CoordinatorConfig};
use ns_lbp::engine::{BackendKind, QosClass};
use ns_lbp::params::synth::synth_params;
use ns_lbp::serve::{Request, Server};
use ns_lbp::testing::synth_frames;

fn main() {
    let fast = std::env::var("NSLBP_BENCH_FAST").is_ok();
    let n_frames = if fast { 64 } else { 256 };
    let shard_counts: &[usize] = if fast { &[1, 4] } else { &[1, 2, 4, 8] };
    let batch_sizes: &[usize] = if fast { &[8] } else { &[1, 8, 32] };
    let backends = [BackendKind::Architectural, BackendKind::Functional];

    // prefer the trained artifact network; otherwise a synthetic one so
    // the bench runs from a bare checkout
    let params = ns_lbp::params::load("artifacts/mnist.params.bin")
        .unwrap_or_else(|_| synth_params(7).1);
    let frames = synth_frames(&params, n_frames, 11).unwrap();
    println!(
        "serve_throughput: {} frames of {}x{}x{}\n",
        frames.len(), params.config.height, params.config.width,
        params.config.in_channels
    );

    let mut table = Table::new(&[
        "backend", "shards", "batch", "fps", "p50_ms", "p95_ms", "p99_ms",
        "mean_batch", "uJ_frame", "mismatches",
    ]);
    for &backend in &backends {
        for &batch in batch_sizes {
            for &shards in shard_counts {
                let mut system = SystemConfig::default();
                system.engine.backend = backend;
                system.serve.shards = shards;
                system.serve.max_batch = batch;
                system.serve.queue_depth = n_frames; // replay never rejects
                system.serve.batch_deadline_us = 2000;
                let server = Server::start(
                    params.clone(),
                    CoordinatorConfig {
                        system,
                        arch: ArchSim { lbp: true, mlp: false,
                                        early_exit: false },
                        shard: None,
                    },
                )
                .unwrap();
                let tickets: Vec<_> = frames
                    .iter()
                    .map(|f| {
                        server.submit(Request::from_frame(f.clone())).unwrap()
                    })
                    .collect();
                for t in tickets {
                    t.wait().unwrap();
                }
                let r = server.drain().unwrap();
                table.row(&[
                    backend.to_string(),
                    shards.to_string(),
                    batch.to_string(),
                    format!("{:.1}", r.throughput_fps),
                    format!("{:.2}", r.p50_ms),
                    format!("{:.2}", r.p95_ms),
                    format!("{:.2}", r.p99_ms),
                    format!("{:.1}", r.mean_batch),
                    format!("{:.3}", r.energy_per_frame_uj),
                    r.arch_mismatches.to_string(),
                ]);
            }
        }
    }
    table.print();
    std::fs::create_dir_all("artifacts/results").ok();
    table.write_tsv("artifacts/results/serve_throughput.tsv").unwrap();
    println!("\nwrote artifacts/results/serve_throughput.tsv");

    // routed two-class scenario: cheap best-effort traffic on the
    // functional path, billed traffic on the architectural path, both
    // through one server — the class-differentiated near-sensor split
    println!("\nrouted two-class (best_effort=functional, \
              billed=architectural):");
    let mut system = SystemConfig::default();
    system.serve.shards = if fast { 2 } else { 4 };
    system.serve.max_batch = 8;
    system.serve.queue_depth = n_frames * 2;
    // shallow best-effort queue so the drop-oldest admission policy is
    // actually exercised under the open-loop replay
    system.serve.classes[QosClass::BestEffort.index()].queue_depth = Some(8);
    system.engine.routing
        .set(QosClass::BestEffort, BackendKind::Functional);
    system.engine.routing
        .set(QosClass::Billed, BackendKind::Architectural);
    let shards = system.serve.shards;
    let server = Server::start(
        params.clone(),
        CoordinatorConfig {
            system,
            arch: ArchSim { lbp: true, mlp: false, early_exit: false },
            shard: None,
        },
    )
    .unwrap();
    let cheap = server.session(0).with_class(QosClass::BestEffort);
    let billed = server.session(1).with_class(QosClass::Billed);
    let tickets: Vec<_> = frames
        .iter()
        .enumerate()
        .map(|(i, f)| {
            let session = if i % 2 == 0 { &cheap } else { &billed };
            session.submit(f.clone()).unwrap()
        })
        .collect();
    drop(cheap);
    drop(billed);
    let mut shed = 0u64;
    for t in tickets {
        match t.wait() {
            Ok(_) => {}
            // drop-oldest shedding under open-loop load; anything else
            // is a real failure
            Err(ns_lbp::Error::Dropped(_)) => shed += 1,
            Err(e) => panic!("serve error: {e}"),
        }
    }
    let r = server.drain().unwrap();
    r.print(&format!("{shards} shard(s), routed"));
    println!("  (drop-oldest shed {shed} best-effort tickets)");
}
