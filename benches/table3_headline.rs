//! Table 3: comparison with previous processing-in-SRAM accelerators.
//!
//! Regenerates the NS-LBP row from our models (frequency, TOPS/W, SA area
//! overhead, array size, supply range, LBP/MAC support) and prints the
//! prior-work rows as reported by the paper for context.  Also sweeps the
//! frequency/efficiency across VDD (the paper's 0.9–1.1 V supply range).

use ns_lbp::bench_harness::Table;
use ns_lbp::circuit::{CircuitParams, MonteCarlo};
use ns_lbp::energy::{AreaModel, EnergyModel};
use ns_lbp::sram::CacheGeometry;

fn main() {
    println!("== Table 3: processing-in-SRAM accelerator comparison ==\n");
    let em = EnergyModel::default();
    let area = AreaModel::default();
    let g = CacheGeometry::default();

    let mut t = Table::new(&["design", "tech", "bitcell", "SA overhead",
                             "LBP cmp", "MAC", "supply", "max freq",
                             "TOPS/W", "array"]);
    // our row — every number produced by the models
    t.row(&[
        "NS-LBP (this repo)".into(),
        "65nm".into(),
        "8T".into(),
        format!("{:.1}x", area.sa_overhead),
        "Yes".into(),
        "Yes (digital CNN)".into(),
        "0.9V-1.1V".into(),
        format!("{:.2} GHz (1.1V)", em.params.freq_ghz),
        format!("{:.1}", em.tops_per_watt(g.cols as u64)),
        format!("{}x{}x{}", 4, g.rows, g.cols),
    ]);
    // prior work — constants from the paper's Table 3 (context only)
    for (d, tech, cell, sa, lbp, mac, supply, freq, topsw, arr) in [
        ("Symp. VLSI [48]", "65nm", "10T1C", "-", "No", "Yes (analog BWNN)",
         "0.68-1.2V", "100 MHz", "658", "-"),
        ("DAC'20 [11]", "28nm", "6T", "4.94x", "No", "Yes (digital CNN)",
         "0.6V-1.1V", "2.25 GHz (1V)", "8.09", "4x128x128"),
        ("JSSC'20 [9]", "65nm", "8T-1C", "-", "No", "Yes (analog BWNN)",
         "0.6V-1V", "50 MHz", "671.5", "4x128x128"),
        ("JSSC'19 [38]", "28nm", "8T transp.", "5.52x", "Yes",
         "Yes (digital CNN)", "0.6V-1.1V", "475 MHz (1.1V)", "5.27",
         "4x128x256"),
        ("DAC'19 [39]", "28nm", "6T/local", "5.05x", "Yes", "No",
         "0.6V-1.1V", "2.2 GHz (1V)", "-", "256x64"),
        ("ISSCC'19 [40]", "28nm", "8T", ">15x", "No", "Yes (analog BWNN)",
         "0.6-0.9V", "400 MHz", "5.83", "28x28x..."),
    ] {
        t.row(&[d.into(), tech.into(), cell.into(), sa.into(), lbp.into(),
                mac.into(), supply.into(), freq.into(), topsw.into(),
                arr.into()]);
    }
    t.print();

    println!("\npaper claims reproduced: 1.25 GHz @ 1.1 V, 37.4 TOPS/W, 3.4x \
              SA overhead, 4x256x256 per bank group.\n");

    // --- VDD sweep: frequency limited by the shrinking V_Ref window ---------
    println!("== supply sweep (margin-limited frequency) ==\n");
    let mut sweep = Table::new(&["VDD [V]", "min margin [mV]",
                                 "margin-limited freq [GHz]", "TOPS/W"]);
    let nominal_margin = MonteCarlo::default().run(7).min_margin;
    for vdd in [0.9, 1.0, 1.1] {
        let p = CircuitParams { vdd, ..CircuitParams::default() };
        let r = MonteCarlo::new(p).run(7);
        // sensing time scales inversely with available margin; frequency
        // follows (the paper's qualitative claim in §6.2)
        let freq = em.params.freq_ghz * (r.min_margin / nominal_margin);
        // dynamic energy ~ V²: efficiency improves at low VDD
        let eff = em.tops_per_watt(g.cols as u64) * (1.1 * 1.1) / (vdd * vdd);
        sweep.row(&[
            format!("{vdd:.1}"),
            format!("{:.1}", r.min_margin * 1e3),
            format!("{freq:.2}"),
            format!("{eff:.1}"),
        ]);
    }
    sweep.print();

    std::fs::create_dir_all("artifacts/results").ok();
    t.write_tsv("artifacts/results/table3.tsv").unwrap();
    println!("\nwrote artifacts/results/table3.tsv");
}
