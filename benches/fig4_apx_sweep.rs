//! Fig. 4: energy consumption vs accuracy vs number of approximated bits
//! (MNIST).
//!
//! Regenerates the paper's trade-off curve: for apx ∈ 0..=4 the LBP-layer
//! energy from (a) the analytic op-count model (Eq. 2) and (b) a measured
//! architectural-simulation run, joined with the trained accuracy column
//! written by `make fig4` (python -m compile.train --fig4) when available.
//!
//! Paper's headline: apx = 2 of 4 mapping bits ⇒ ~42% LBP-layer energy
//! saving at 1.3 pt accuracy cost.

use ns_lbp::baselines::{cost, Design};
use ns_lbp::bench_harness::Table;
use ns_lbp::coordinator::{Coordinator, CoordinatorConfig};
use ns_lbp::params;
use ns_lbp::rng::Xoshiro256;
use ns_lbp::sensor::{ReplaySensor, SensorConfig};
use ns_lbp::sram::CacheGeometry;

fn accuracy_column() -> Vec<Option<f64>> {
    // artifacts/fig4_accuracy.tsv: "apx\taccuracy" written by make fig4
    let mut col = vec![None; 5];
    if let Ok(text) = std::fs::read_to_string("artifacts/fig4_accuracy.tsv") {
        for line in text.lines().skip(1) {
            let mut it = line.split('\t');
            if let (Some(a), Some(acc)) = (it.next(), it.next()) {
                if let (Ok(a), Ok(acc)) = (a.parse::<usize>(), acc.parse::<f64>()) {
                    if a < col.len() {
                        col[a] = Some(acc);
                    }
                }
            }
        }
    }
    col
}

/// Measured energy per frame from the architectural simulator.
fn measured_energy_uj(apx: usize) -> f64 {
    let mut p = params::load("artifacts/mnist.params.bin")
        .expect("run `make artifacts` first");
    p.config.apx_code = apx;
    p.config.apx_pixel = apx;
    let cfg = p.config;
    let coord = Coordinator::new(p, CoordinatorConfig::default()).unwrap();
    let scfg = SensorConfig {
        rows: cfg.height, cols: cfg.width, channels: cfg.in_channels,
        skip_lsbs: cfg.apx_pixel, ..Default::default()
    };
    let mut rng = Xoshiro256::new(4);
    let scenes: Vec<Vec<f64>> = (0..4)
        .map(|_| (0..scfg.pixels()).map(|_| rng.next_f64()).collect())
        .collect();
    let mut sensor = ReplaySensor::new(scfg, scenes, 1).unwrap();
    let (_, summary) = coord.run(&mut sensor, 4).unwrap();
    assert_eq!(summary.arch_mismatches, 0);
    summary.energy_per_frame_uj()
}

fn main() {
    println!("== Fig. 4: energy vs accuracy vs approximated bits (MNIST) ==\n");
    let g = CacheGeometry::default();
    let acc = accuracy_column();

    let base_model = cost(Design::NsLbpApLbp { apx: 0 }, "mnist", &g)
        .unwrap()
        .energy_uj();
    let base_meas = measured_energy_uj(0);

    let mut table = Table::new(&["apx", "model energy [µJ]", "model saving",
                                 "measured energy [µJ]", "measured saving",
                                 "accuracy [%]"]);
    for apx in 0..=4usize {
        let model = cost(Design::NsLbpApLbp { apx: apx as u64 }, "mnist", &g)
            .unwrap()
            .energy_uj();
        let meas = measured_energy_uj(apx);
        table.row(&[
            apx.to_string(),
            format!("{model:.3}"),
            format!("{:.1}%", 100.0 * (1.0 - model / base_model)),
            format!("{meas:.3}"),
            format!("{:.1}%", 100.0 * (1.0 - meas / base_meas)),
            acc[apx].map_or("run `make fig4`".into(), |a| format!("{a:.2}")),
        ]);
    }
    table.print();
    std::fs::create_dir_all("artifacts/results").ok();
    table.write_tsv("artifacts/results/fig4.tsv").unwrap();
    println!("\npaper: apx=2 ⇒ ~42% LBP-layer energy saving, −1.3 pt accuracy");
    println!("wrote artifacts/results/fig4.tsv");
}
