//! Fig. 9: post-layout transient simulation of an NS-LBP sub-array
//! executing the XOR3-based comparison.
//!
//! Regenerates the waveform series (RBL discharge per input combination,
//! the three references, the SA decision at the 400 ps strobe) and
//! micro-benches the behavioral circuit model itself.

use ns_lbp::bench_harness::{black_box, Bench, Table};
use ns_lbp::circuit::{sense, CircuitParams, SENSE_DELAY_PS};

fn main() {
    let p = CircuitParams::default();
    println!("== Fig. 9: RBL transients + single-cycle XOR3 ==\n");

    let mut table = Table::new(&["t [ps]", "\"000\" [V]", "\"001\" [V]",
                                 "\"011\" [V]", "\"111\" [V]"]);
    let mut t = 0.0;
    while t <= 800.0 {
        table.row(&[
            format!("{t:.0}"),
            format!("{:.3}", p.rbl_waveform(0, t).unwrap()),
            format!("{:.3}", p.rbl_waveform(1, t).unwrap()),
            format!("{:.3}", p.rbl_waveform(2, t).unwrap()),
            format!("{:.3}", p.rbl_waveform(3, t).unwrap()),
        ]);
        t += 80.0;
    }
    table.print();

    let [r1, r2, r3] = p.refs();
    println!("\nreferences: V_R1 {:.0} mV, V_R2 {:.0} mV, V_R3 {:.0} mV",
             r1 * 1e3, r2 * 1e3, r3 * 1e3);
    println!("settled levels (paper): 280 / 495 / 735 / 950 mV — model: \
              {:.0} / {:.0} / {:.0} / {:.0} mV",
             p.rbl_level(0).unwrap() * 1e3, p.rbl_level(1).unwrap() * 1e3,
             p.rbl_level(2).unwrap() * 1e3, p.rbl_level(3).unwrap() * 1e3);

    let mut dec = Table::new(&["ones", "RBL@strobe [V]", "OR3", "MAJ3", "AND3",
                               "XOR3"]);
    for ones in 0..=3usize {
        let v = p.rbl_waveform(ones, SENSE_DELAY_PS).unwrap();
        let sa = sense(&p, ones, 0.0).unwrap();
        dec.row(&[
            ones.to_string(),
            format!("{v:.3}"),
            (sa.or3 as u8).to_string(),
            (sa.maj3 as u8).to_string(),
            (sa.and3 as u8).to_string(),
            (sa.xor3() as u8).to_string(),
        ]);
    }
    println!();
    dec.print();
    println!("\nsense delay {} ps < cycle {} ps at {} GHz (paper: ~400 ps)",
             SENSE_DELAY_PS, p.cycle_ps(), p.freq_ghz);

    std::fs::create_dir_all("artifacts/results").ok();
    table.write_tsv("artifacts/results/fig9.tsv").unwrap();
    println!("wrote artifacts/results/fig9.tsv\n");

    // --- microbenchmark of the model itself --------------------------------
    let mut b = Bench::new("fig9");
    b.run("rbl_waveform", || {
        let mut acc = 0.0;
        for ones in 0..4 {
            acc += p.rbl_waveform(ones, black_box(400.0)).unwrap();
        }
        acc
    });
    b.run("sense_decision", || {
        let mut n = 0u32;
        for ones in 0..4 {
            n += sense(&p, ones, 0.0).unwrap().xor3() as u32;
        }
        n
    });
}
